"""Batched engine for the event-driven simulator (DESIGN.md §11-§12).

The reference engine (train/simulator.py) executes one worker event (or one
synchronous-round grad step) per Python iteration — a couple of jitted
dispatches over a per-replica pytree each — which tops out around 8–16
workers.  This engine keeps the *exact same host-side machinery* (heap
order, rng draw order, LinkTimeModel draws, EMA updates, Monitor schedule,
round barriers) but stacks all M replicas/momenta into leading-M pytrees
and executes many events per device dispatch.  It covers every registered
strategy:

* **async gossip** (netmax / adpsgd family) — cohorts of causally-
  independent events, one donated jitted vmapped call per cohort
  (``Algorithm.batched_variant == "gossip"``);
* **ps-async** — the ``"ps-serial"`` variant: a cohort's grad steps run
  stacked, and the PS running average is folded as a *serialized chain*
  over the cohort's ``x_half`` rows in exact pop order inside the same
  dispatch (``s <- s + w (x_k - s)`` — bit-for-bit the reference's
  event-at-a-time recurrence, only the grad math is vmapped);
* **synchronous rounds** (ps-sync / allreduce / prague) — ``run_batched_sync``
  executes each round as one dispatch: vmapped grad steps + a one-segment-
  mean ``reduce_groups_stacked``; rounds between record boundaries are
  additionally scan-fused.

Scheduling of the async families works in two layers:

* **Windows** — events are *drawn* strictly in heap-pop order (peer
  selection, batch indices, link-time jitter, EMA updates), so every host
  rng consumes bits in exactly the reference order.  A window extends until
  the next *boundary*: a Monitor wake (the policy refresh changes
  subsequent peer draws), a ``record_every`` evaluation (which must observe
  the state after exactly that many events), or the event cap.
* **Cohorts** — each window is level-scheduled into causally-independent
  event sets.  One fused dispatch gathers every pull from *pre-cohort*
  replica rows, computes, then scatters all actor rows, so executing a
  level against pre-cohort state must be indistinguishable from the
  reference's strictly-sequential execution.  An event's level is one plus
  the maximum over its hazards, all expressed on replica rows (an event
  *writes* its actor's row and *reads* its actor + peer rows):

  1. write-after-write / read-after-write on the actor row — a worker's
     next event both rewrites and grad-reads the row its previous event
     wrote, so per-worker order is strict;
  2. read-after-write on the peer row — the reference serves a pull the
     *post*-update value of any peer event that already ran, so a pull
     must land in a strictly later level than its peer row's last write;
  3. write-after-read on the actor row — an earlier-popped pull of this
     row must not see this event's write, so the write's level is at
     least the reader's (the *same* level is fine: gathers happen before
     the scatter).

  The ``"ps-serial"`` variant relaxes rule 2 on the serialized row: pushes
  into the PS may share a level (the fused step folds them in pop order),
  they only need their level to be *non-decreasing* in pop order; the PS
  node's own grad step reads the PS row outside the chain, so it must land
  strictly after every prior push's level.

* **Chains** — consecutive batch-length-homogeneous levels whose row
  buckets stay within a 2x band are fused into one ``lax.scan`` dispatch
  carrying the donated ``(R, Mom)`` stacked trees, with a uniform row
  bucket per chain (the band's max, so wasted pad rows stay <= ~1/2) and
  the chain length padded to ~1.5x-stepped buckets via no-op levels
  (valid=0 rows).  Both the wide plateau at the head of a window and the
  busiest worker's long sequential tail of tiny levels collapse into a
  handful of dispatches (`SimResult.dispatches` vs the logical
  `SimResult.cohorts`).

The engines produce identical `times`/`events`/`comm_time` and
near-identical losses (tests/test_engines.py pins every registered
strategy).

Cohorts are padded to ~1.5x-stepped size buckets (≤ M) so only O(log M)
XLA programs are compiled; pad rows use distinct idle workers with a
validity mask so the scatter is conflict-free.  The mixing math inside the
fused step is ``Algorithm.mix_stacked_tree`` — the same leaf rule the SPMD
trainer jits — or, for identity-delta strategies with
``SimConfig.use_mix_kernel``, the fused ``kernels/ops.mix_rows`` path
(Pallas ``gossip_mix_rows`` on TPU).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.algos.base import Algorithm
from repro.core.monitor import IterationTimeEMA
from repro.scenarios.driver import (
    apply_action,
    attempt_fails,
    monitor_boundary,
    notify_monitor,
    prepare_monitor,
)
from repro.scenarios.timeline import ScenarioCursor
from repro.train import simulator as _sim
from repro.train.elastic import reseed_row
from repro.train.events import EventHeap

tree_map = jax.tree_util.tree_map

# Compiled cohort steps, keyed by (Algorithm.cache_token(), lr, momentum,
# use_mix_kernel, batched_variant, serial row).  Reused across simulate()
# calls so repeated runs (tests, benchmarks) don't re-trace identical
# programs.  Each entry is a (step, chain_step) pair sharing one traced
# body: ``step`` executes a single cohort, ``chain_step`` a lax.scan over a
# stacked run of cohorts.
_STEP_CACHE: dict = {}


def _bucket(n: int, cap: int) -> int:
    """Smallest ~1.5x-stepped bucket >= n, capped at M (pad rows must be
    distinct).  Finer than powers of two: the fused step is compute-bound,
    so padded rows are wasted FLOPs, while each extra bucket only costs one
    more (small) XLA program."""
    b = 1
    while b < n:
        b = b * 2 if b < 4 else (b * 3 + 1) // 2
    return min(b, cap)


#: Longest run of cohorts one scan-fused dispatch may carry; longer runs
#: flush and start a new chain (bounds per-dispatch host packing and the
#: scan's unrolled cost).
_CHAIN_CAP = 64

#: Shortest singleton-level run worth the dedicated burst dispatch (below
#: this the band chain packs them just as well).
_BURST_MIN = 4

#: Longest singleton run one burst dispatch may carry.  Bursts move one row
#: per step, so they can afford longer scans than full-stack chains.
_BURST_CAP = 128


def _chain_bucket(n: int, cap: int = _CHAIN_CAP) -> int:
    """~1.5x-stepped bucket for chain (scan) lengths, capped: pad levels
    are cheap no-ops but each distinct length is one XLA program."""
    b = 2
    while b < n:
        b = (b * 3 + 1) // 2
    return min(b, cap)


def _make_cohort_body(algo: Algorithm, lr: float, mu: float,
                      use_mix_kernel: bool, sr: int | None):
    """Build the untraced fused-step body for one strategy.

    Signature: (R, Mom, dx, dy, ints, w) -> (R, Mom) where R/Mom leaves are
    (M, ...) stacked replicas/momenta, dx/dy the device-resident training
    set, and the per-cohort operands cross the host boundary as just two
    arrays: ``ints`` (K, 3+B) i32 packing [actor row, peer row (gossip) or
    push flag (ps-serial), valid, batch indices...] and ``w`` (K,) f32 mix
    weights (0 ⇒ no communication).  valid=0 marks padding: the row is
    written back unchanged.
    """
    vgrad = jax.vmap(jax.value_and_grad(_sim.ce_loss))
    identity_delta = type(algo).delta_transform is Algorithm.delta_transform
    variant = algo.batched_variant

    def keep_valid(valid):
        def f(new, old):
            v = valid.reshape((-1,) + (1,) * (new.ndim - 1))
            return jnp.where(v, new, old)

        return f

    def grad_half(R, Mom, dx, dy, ints):
        """Shared front half: stacked vmapped grad + momentum + local step."""
        idx = ints[:, 0]
        valid = ints[:, 2] > 0
        xb, yb = dx[ints[:, 3:]], dy[ints[:, 3:]]
        h = tree_map(lambda l: l[idx], R)
        mom = tree_map(lambda l: l[idx], Mom)
        _, grads = vgrad(h, xb, yb)
        new_m = tree_map(lambda m_, g: mu * m_ + g, mom, grads)
        x_half = tree_map(lambda p, m_: p - lr * m_, h, new_m)
        return idx, valid, h, mom, new_m, x_half

    if variant == "ps-serial":

        def body(R, Mom, dx, dy, ints, w):
            idx, valid, h, mom, new_m, x_half = grad_half(R, Mom, dx, dy, ints)
            is_push = (ints[:, 1] > 0) & valid
            is_set = valid & ~is_push & (idx == sr)
            s0 = tree_map(lambda l: l[sr], R)

            def chain_op(s, xs):
                xk, pk, tk, wk = xs

                def leaf_s(s_l, x_l):
                    wl = wk.astype(s_l.dtype)
                    # == Algorithm.mix(s, x, w), delta_transform included
                    fold = s_l + wl * algo.delta_transform(x_l - s_l)
                    return jnp.where(pk, fold, jnp.where(tk, x_l, s_l))

                s_new = tree_map(leaf_s, s, xk)
                val = tree_map(
                    lambda sn, x_l: jnp.where(pk, sn, x_l), s_new, xk
                )
                return s_new, val

            s_fin, vals = jax.lax.scan(chain_op, s0, (x_half, is_push, is_set, w))
            vals = tree_map(keep_valid(valid), vals, h)
            new_m = tree_map(keep_valid(valid), new_m, mom)
            R = tree_map(lambda l, v: l.at[idx].set(v), R, vals)
            Mom = tree_map(lambda l, v: l.at[idx].set(v), Mom, new_m)
            wrote = jnp.any(is_push | is_set)
            R = tree_map(
                lambda l, sf: l.at[sr].set(jnp.where(wrote, sf, l[sr])), R, s_fin
            )
            return R, Mom

    else:

        def mix(x_half, pulled, w):
            if use_mix_kernel and identity_delta:
                from repro.kernels import ops as kops

                return kops.gossip_mix_tree(x_half, pulled, w)
            return algo.mix_stacked_tree(x_half, pulled, w)

        def body(R, Mom, dx, dy, ints, w):
            idx, valid, h, mom, new_m, x_half = grad_half(R, Mom, dx, dy, ints)
            pulled = tree_map(lambda l: l[ints[:, 1]], R)  # pre-cohort peers
            mixed = mix(x_half, pulled, w)
            mixed = tree_map(keep_valid(valid), mixed, h)
            new_m = tree_map(keep_valid(valid), new_m, mom)
            R = tree_map(lambda l, v: l.at[idx].set(v), R, mixed)
            Mom = tree_map(lambda l, v: l.at[idx].set(v), Mom, new_m)
            return R, Mom

    return body


def _make_burst_body(algo: Algorithm, lr: float, mu: float, sr: int | None):
    """Singleton-run chain step: a stretch of consecutive singleton levels.

    A full-tree dispatch per singleton level moves the whole (M, ...) stack
    to advance one row — the dominant cost in two real regimes: the busiest
    gossip worker's inherently-sequential tail, and ps-async's congested-PS
    limit where the PS node's fast local steps outnumber pushes ~20:1.
    Burst bodies instead scan over the run carrying only the state that
    actually chains, touching the stacked tree O(1) times:

    * gossip — the run belongs to ONE worker: carry its (row, momentum);
      peers are gathered from the scan-constant pre-burst stack (sound: the
      run's levels contain no other events, so no peer row changes
      mid-burst).  Signature (R, Mom, dx, dy, i, ints, w), ``i`` the actor,
      ``ints`` (L, 2+B) i32 [peer row, valid, batch indices...].
    * ps-serial — the run may mix actors (PS local steps + pushes from
      distinct workers): carry the serialized (PS row, PS momentum); each
      pusher's row/momentum is gathered from the pre-burst stack (sound:
      the host-side run grouping breaks the run before any non-PS actor
      repeats), its post-push value emitted as a scan output and scattered
      once after the scan (PS-local steps scatter nothing — their effect is
      the carry).  Signature (R, Mom, dx, dy, ints, w), ``ints`` (L, 3+B)
      i32 [actor row, push flag, valid, batch indices...].
    """
    grad = jax.value_and_grad(_sim.ce_loss)

    def keep(valid, new, old):
        return tree_map(lambda a, b: jnp.where(valid, a, b), new, old)

    def grad_half(row, mom, xb, yb):
        _, g = grad(row, xb, yb)
        mom2 = tree_map(lambda m_, gg: mu * m_ + gg, mom, g)
        x_half = tree_map(lambda p, m_: p - lr * m_, row, mom2)
        return mom2, x_half

    if algo.batched_variant == "ps-serial":

        def body(R, Mom, dx, dy, ints, w):
            s0 = tree_map(lambda l: l[sr], R)
            ms0 = tree_map(lambda l: l[sr], Mom)

            def f(carry, xs):
                s, mom_s = carry
                ints_k, wk = xs
                actor = ints_k[0]
                valid = ints_k[2] > 0
                push = (ints_k[1] > 0) & valid
                is_ps = valid & ~push & (actor == sr)
                # PS-local steps read the carried chain state; pushes read
                # their own (pre-burst) row.
                row = tree_map(
                    lambda l, s_l: jnp.where(is_ps, s_l, l[actor]), R, s
                )
                mom = tree_map(
                    lambda l, m_l: jnp.where(is_ps, m_l, l[actor]), Mom, mom_s
                )
                mom2, xh = grad_half(row, mom, dx[ints_k[3:]], dy[ints_k[3:]])
                s2 = tree_map(
                    lambda s_l, x_l: jnp.where(
                        push,
                        # == Algorithm.mix(s, x, w), delta_transform included
                        s_l
                        + wk.astype(s_l.dtype) * algo.delta_transform(x_l - s_l),
                        jnp.where(is_ps, x_l, s_l),
                    ),
                    s, xh,
                )
                mom_s2 = keep(is_ps, mom2, mom_s)
                row_out = tree_map(
                    lambda s_l, x_l: jnp.where(push, s_l, x_l), s2, xh
                )
                # Rows to scatter post-scan: pushes (and any plain non-PS
                # local step); PS-local steps ride the carry.  Out-of-range
                # sentinel + mode="drop" skips the rest.
                sc = jnp.where(valid & ~is_ps, actor, jnp.int32(2**30))
                return (s2, mom_s2), (row_out, mom2, sc)

            (s, mom_s), (rows, moms, sc) = jax.lax.scan(f, (s0, ms0), (ints, w))
            R = tree_map(lambda l, v: l.at[sc].set(v, mode="drop"), R, rows)
            Mom = tree_map(lambda l, v: l.at[sc].set(v, mode="drop"), Mom, moms)
            R = tree_map(lambda l, v: l.at[sr].set(v), R, s)
            Mom = tree_map(lambda l, v: l.at[sr].set(v), Mom, mom_s)
            return R, Mom

    else:

        def body(R, Mom, dx, dy, i, ints, w):
            row = tree_map(lambda l: l[i], R)
            mom = tree_map(lambda l: l[i], Mom)

            def f(carry, xs):
                row, mom = carry
                ints_k, wk = xs
                valid = ints_k[1] > 0
                mom2, xh = grad_half(row, mom, dx[ints_k[2:]], dy[ints_k[2:]])
                pulled = tree_map(lambda l: l[ints_k[0]], R)  # pre-burst peers
                # THE leaf rule (Algorithm.mix_stacked_tree), applied to a
                # single row via a length-1 leading axis so an overridden
                # mix stays consistent with the cohort path.
                mixed = tree_map(
                    lambda l: l[0],
                    algo.mix_stacked_tree(
                        tree_map(lambda l: l[None], xh),
                        tree_map(lambda l: l[None], pulled),
                        wk[None],
                    ),
                )
                return (keep(valid, mixed, row), keep(valid, mom2, mom)), None

            (row, mom), _ = jax.lax.scan(f, (row, mom), (ints, w))
            R = tree_map(lambda l, v: l.at[i].set(v), R, row)
            Mom = tree_map(lambda l, v: l.at[i].set(v), Mom, mom)
            return R, Mom

    return body


#: Compiled full-M masked steps for the device-sharded path, keyed by
#: (Algorithm.cache_token(), lr, momentum).
_SHARDED_CACHE: dict = {}


def _sharded_steps(algo: Algorithm, lr: float, mu: float):
    """Fused steps for the device-sharded gossip path (DESIGN.md §16).

    Operands are full-M masked vectors instead of packed cohorts — perm
    (M,) peer rows (identity for idle workers), w (M,) mix weights, valid
    (M,) actor mask, bidx (M, B) batch indices — so every array keeps the
    stacked (M, ...) leading axis and shards row-wise over the mesh with
    no host-side gather of remote rows.  Idle rows ride through unchanged
    (``where(valid, ...)``); actors compute exactly the cohort body's
    grad + momentum + mix, so the trajectory matches the packed path to
    float tolerance.  Three entry points:

    * ``full``   — gather-based pull inside one jitted step (any D); the
      cross-shard ``R[perm]`` lowers to GSPMD collectives.
    * ``half``   — grad/momentum half-step (perm-independent), used with
    * ``commit`` — mix + masked write-back, fed by an eager
      ``repro.dist.pull_ppermute`` between the two when the mesh has one
      worker per slot (ppermute pairs are static, so that lowering lives
      outside the jitted steps).
    """
    key = (algo.cache_token(), float(lr), float(mu))
    entry = _SHARDED_CACHE.get(key)
    if entry is not None:
        return entry
    vgrad = jax.vmap(jax.value_and_grad(_sim.ce_loss))

    def half(R, Mom, dx, dy, bidx):
        _, grads = vgrad(R, dx[bidx], dy[bidx])
        new_m = tree_map(lambda m_, g: mu * m_ + g, Mom, grads)
        x_half = tree_map(lambda p, m_: p - lr * m_, R, new_m)
        return x_half, new_m

    def commit(R, Mom, x_half, new_m, pulled, w, valid):
        mixed = algo.mix_stacked_tree(x_half, pulled, w)

        def keep(new, old):
            v = valid.reshape((-1,) + (1,) * (new.ndim - 1))
            return jnp.where(v, new, old)

        return tree_map(keep, mixed, R), tree_map(keep, new_m, Mom)

    def full(R, Mom, dx, dy, perm, w, valid, bidx):
        x_half, new_m = half(R, Mom, dx, dy, bidx)
        pulled = tree_map(lambda l: l[perm], R)  # pre-cohort peer rows
        return commit(R, Mom, x_half, new_m, pulled, w, valid)

    entry = (
        jax.jit(full, donate_argnums=(0, 1)),
        jax.jit(half),
        jax.jit(commit, donate_argnums=(0, 1)),
    )
    _SHARDED_CACHE[key] = entry
    return entry


def _steps_for(algo: Algorithm, lr: float, mu: float, use_mix_kernel: bool,
               sr: int | None):
    if algo.batched_variant not in ("gossip", "ps-serial"):
        # A variant this engine doesn't implement must fail loudly — falling
        # through to the gossip body would silently compute wrong updates.
        raise NotImplementedError(
            f"batched_variant {algo.batched_variant!r} of {algo.name!r} is "
            "not implemented by the batched engine; use engine='reference'"
        )
    key = (algo.cache_token(), float(lr), float(mu), bool(use_mix_kernel),
           algo.batched_variant, sr)
    entry = _STEP_CACHE.get(key)
    if entry is None:
        body = _make_cohort_body(algo, lr, mu, use_mix_kernel, sr)
        step = jax.jit(body, donate_argnums=(0, 1))

        def chain_body(R, Mom, dx, dy, ints_seq, w_seq):
            def f(carry, xs):
                ints, w = xs
                return body(carry[0], carry[1], dx, dy, ints, w), None

            carry, _ = jax.lax.scan(f, (R, Mom), (ints_seq, w_seq))
            return carry

        chain = jax.jit(chain_body, donate_argnums=(0, 1))
        burst = jax.jit(_make_burst_body(algo, lr, mu, sr),
                        donate_argnums=(0, 1))
        entry = (step, chain, burst)
        _STEP_CACHE[key] = entry
    return entry


@jax.jit
def _eval_stacked(R, eval_x, eval_y):
    mean_p = tree_map(lambda l: l.mean(axis=0), R)
    loss = _sim.ce_loss(mean_p, eval_x, eval_y)
    logits = _sim.mlp_apply(mean_p, eval_x)
    acc = (jnp.argmax(logits, -1) == eval_y).mean()
    return loss, acc


def run_batched(
    algo: Algorithm,
    cfg,
    state,
    rng: np.random.Generator,
    p0,
    link_model,
    data_x: np.ndarray,
    data_y: np.ndarray,
    part_idx,
    eval_x: np.ndarray,
    eval_y: np.ndarray,
    record_every: int,
    res,
    cohort_log: list | None = None,
):
    """Run the async event loop on stacked state; mutates and returns ``res``.

    ``cohort_log``, when a list, receives one dict per cohort (actors,
    peers, event range, boundary flag) — the scheduler-invariant tests
    introspect it.  Chain fusion never changes the logical cohort structure
    (the log and ``res.cohorts`` are identical with ``cfg.fuse_chains`` on
    or off); it only packs consecutive levels into fewer device dispatches
    (``res.dispatches``).
    """
    M = cfg.n_workers
    total = cfg.total_events
    variant = algo.batched_variant
    sr = algo.serial_row(state) if variant == "ps-serial" else None
    fuse = getattr(cfg, "fuse_chains", True)

    # Stacked replicas: all workers start from the same p0, like the
    # reference engine's per-replica copies.
    R = tree_map(lambda l: jnp.array(jnp.broadcast_to(l[None], (M,) + l.shape)), p0)
    Mom = tree_map(lambda l: jnp.zeros((M,) + l.shape, l.dtype), p0)
    step, chain_step, burst_step = _steps_for(algo, cfg.lr, cfg.momentum,
                                              cfg.use_mix_kernel, sr)

    # Device-sharded path (SimConfig.shard_workers; DESIGN.md §16): rows of
    # the stacked pytree live split across the local mesh, and cohorts run
    # as full-M masked steps through _sharded_steps.
    shard = bool(getattr(cfg, "shard_workers", False))
    mesh = None
    if shard:
        from jax.sharding import Mesh, NamedSharding
        from jax.sharding import PartitionSpec as P

        if variant != "gossip":
            raise ValueError(
                "cfg.shard_workers supports async gossip-family strategies "
                f"only, not {algo.name!r} (variant {variant!r})"
            )
        devs = np.array(jax.devices())
        if M % len(devs) != 0:
            raise ValueError(
                f"cfg.shard_workers needs n_workers ({M}) divisible by the "
                f"device count ({len(devs)})"
            )
        mesh = Mesh(devs, ("workers",))
        rows = NamedSharding(mesh, P("workers"))
        R = tree_map(lambda l: jax.device_put(l, rows), R)
        Mom = tree_map(lambda l: jax.device_put(l, rows), Mom)
        sh_full, sh_half, sh_commit = _sharded_steps(algo, cfg.lr, cfg.momentum)

    monitor = algo.make_monitor(cfg, M, d=state.d) if algo.wants_monitor(cfg) else None
    # Worker-side EMA matrices are M x (M,)-vectors — O(M^2) host memory.
    # They only ever feed Monitor.collect, so monitor-less runs (the fleet
    # sizes in benchmarks/run.py --suite simulator) skip them entirely;
    # EMA updates consume no rng, so this is invisible to parity.
    emas = ([IterationTimeEMA(M, beta=cfg.ema_beta) for _ in range(M)]
            if monitor is not None else None)
    next_monitor = monitor.schedule_period if monitor else float("inf")
    prepare_monitor(monitor, link_model)

    # Scenario machinery (repro.scenarios): the cursor's boundaries are
    # window breaks — no fused cohort or scan chain ever spans a scenario
    # boundary, so churn actions land between device dispatches exactly
    # where the reference loop applies them.
    scn = link_model.compiled_scenario
    cursor = ScenarioCursor(scn) if scn is not None else None
    active = set(range(M))

    def reseed(w, src):
        nonlocal R, Mom
        R, Mom = reseed_row(R, Mom, w, src)

    ex, ey = jnp.asarray(eval_x), jnp.asarray(eval_y)
    # Training set lives on device; per-cohort batches are gathered there
    # from (K, B) index arrays instead of shipping (K, B, D) floats.
    dx, dy = jnp.asarray(data_x), jnp.asarray(data_y)

    def eval_now(t, ev):
        loss, acc = _eval_stacked(R, ex, ey)
        res.times.append(t)
        res.losses.append(float(loss))
        res.accs.append(float(acc))
        res.events.append(ev)

    bsz = [min(cfg.batch_size, len(part_idx[i])) for i in range(M)]

    heap = EventHeap()
    for i in range(M):
        heap.push(rng.exponential(0.005), i)

    ev = 0
    t = 0.0
    window_cap = max(4 * M, 64)  # backstop when record_every is huge

    def draw_event():
        """Pop + fully draw the next event, consuming every host rng in
        reference order (peer, batch, link jitter, EMA, reschedule).  A
        pull over a scenario-dead link is priced as the timeout, notifies
        the Monitor, and executes as a plain local step (communicated
        False => the fused step self-pulls with w=0)."""
        nonlocal ev, t, next_monitor
        t_ev, i = heap.pop()
        ev += 1
        m = algo.select_peer(state, i, rng)
        bidx = rng.choice(part_idx[i], size=bsz[i])
        failed = scn is not None and attempt_fails(
            link_model, algo, state, i, m, t_ev
        )
        communicated = (not failed) and algo.would_communicate(state, i, m)
        w = algo.mix_weight(state, cfg, i, m) if communicated else 0.0
        timing = algo.event_timing(
            state, cfg, link_model, i, m, communicated or failed, t_ev
        )
        if cfg.trace:
            kind = "timeout" if failed else (
                "pull" if communicated else "local"
            )
            res.trace_events.append(
                (t_ev, timing.duration, i, m if m is not None else -1, kind,
                 timing.comm, timing.compute, timing.net)
            )
        res.comm_time += timing.comm
        res.compute_time += timing.compute
        if failed:
            res.failed_pulls.append((t_ev, i, m))
            next_monitor = notify_monitor(
                monitor, i, m, t_ev, next_monitor, link_model=link_model
            )
        if emas is not None and algo.reports_ema and m is not None:
            emas[i].update(m, timing.duration)
        heap.push(t_ev + timing.duration, i)
        t = t_ev
        return (t_ev, i, m, float(w), communicated, bidx, ev)

    def schedule_window(window):
        """Level-schedule a window into causally-independent cohorts.

        One O(1)-per-event pass in pop order; see the module docstring for
        the three hazard rules (plus the serialized-row relaxation for the
        ps-serial variant).  Returns cohorts ordered by level, each a
        pop-ordered event list with all-distinct actors; executing them in
        order with gather-before-scatter semantics (and in-dispatch
        pop-order folding of the serialized row) reproduces the reference's
        strictly-sequential result exactly.
        """
        last_write: dict[int, int] = {}  # row -> level of its latest write
        max_read: dict[int, int] = {}  # row -> highest level that read it
        last_sw = 0  # level of the serialized row's latest write (ps-serial)
        groups: list[list] = []
        level_blen: list = []  # batch length per level (one dispatch each)
        for e in window:
            _, i, m, _, communicated, bidx, _ = e
            lvl = last_write.get(i, 0) + 1  # rules 1 (WAW/RAW on actor row)
            if communicated:
                if sr is not None and m == sr:
                    # Serialized push: may share the last writer's level —
                    # the fused step folds same-level pushes in pop order —
                    # but must never land in an earlier one.
                    lvl = max(lvl, last_sw)
                else:
                    lvl = max(lvl, last_write.get(m, 0) + 1)  # rule 2 (RAW peer)
                    # rule 3 bookkeeping happens below via max_read
            elif sr is not None and i == sr:
                # The PS node's own grad step reads the PS row *outside* the
                # chain (pre-level gather), so every prior push must have
                # scattered already.
                lvl = max(lvl, last_sw + 1)
            lvl = max(lvl, max_read.get(i, 0))  # rule 3 (WAR on actor row)
            # One fused call needs a uniform batch length, and rule 3's
            # same-level exemption is only sound if the whole level IS one
            # call (gather-before-scatter) — so batch length is part of a
            # level's identity.  Raising a level past a mismatched one is
            # always safe: every hazard above is a lower bound, and the
            # bookkeeping below records the *final* level.
            blen = len(bidx)
            while lvl <= len(level_blen) and level_blen[lvl - 1] != blen:
                lvl += 1
            last_write[i] = lvl
            if communicated:
                if sr is not None and m == sr:
                    last_sw = max(last_sw, lvl)
                else:
                    max_read[m] = max(max_read.get(m, 0), lvl)
            if sr is not None and i == sr:
                last_sw = max(last_sw, lvl)  # PS-local event rewrites the row
            while len(groups) < lvl:  # lvl <= len(groups)+1: no gaps
                groups.append([])
                level_blen.append(blen)
            groups[lvl - 1].append(e)
        return groups

    def pack(cohort, B):
        """Pack one cohort into (ints, w) operands padded to bucket B."""
        K = len(cohort)
        actors = {e[1] for e in cohort}
        blen = len(cohort[0][5])
        ints = np.zeros((B, 3 + blen), np.int32)
        w = np.zeros(B, np.float32)
        for k, e in enumerate(cohort):
            ints[k, 0] = e[1]
            if sr is not None:
                ints[k, 1] = 1 if e[4] else 0  # push flag
            else:
                # self-pull (w=0) for non-communicating events
                ints[k, 1] = e[2] if e[4] else e[1]
            ints[k, 2] = 1
            ints[k, 3:] = e[5]
            w[k] = e[3]
        if B > K:  # pad rows: distinct idle workers, written back unchanged
            # First B-K non-actor rows, ascending — an incremental walk, so
            # a fleet-sized M doesn't pay an O(M) scan per tiny cohort.
            free = np.empty(B - K, np.int32)
            n, r = 0, 0
            while n < B - K:
                if r not in actors:
                    free[n] = r
                    n += 1
                r += 1
            ints[K:, 0] = free
            if sr is None:
                ints[K:, 1] = free
        return ints, w

    def dispatch_sharded(cohort):
        """Execute one cohort as a full-M masked step on the worker mesh.

        Host packing here is O(M) per cohort — acceptable because the
        sharded path exists to distribute device memory, not to minimize
        host work (the fleet benchmarks run unsharded).  The ppermute
        lowering only engages at one worker per mesh slot; its shard_map
        pairs are static, so each distinct peer map is its own program —
        a demonstration lowering, with the sharded gather as the general
        path."""
        nonlocal R, Mom
        blen = len(cohort[0][5])
        perm = np.arange(M, dtype=np.int32)
        wv = np.zeros(M, np.float32)
        valid = np.zeros(M, bool)
        bidx = np.zeros((M, blen), np.int32)
        for e in cohort:
            i = e[1]
            perm[i] = e[2] if e[4] else i
            wv[i] = e[3]
            valid[i] = True
            bidx[i] = e[5]
        if mesh.size == M and len(set(perm.tolist())) == M:
            # One worker per mesh slot AND the peer map is a true
            # permutation (ppermute forbids repeated sources — see the
            # repro.dist.gossip docstring): pull point-to-point.
            from repro.dist.gossip import pull_ppermute

            x_half, new_m = sh_half(R, Mom, dx, dy, bidx)
            pulled = pull_ppermute(R, tuple(int(p) for p in perm),
                                   mesh, ("workers",))
            R, Mom = sh_commit(R, Mom, x_half, new_m, pulled, wv, valid)
        else:
            R, Mom = sh_full(R, Mom, dx, dy, perm, wv, valid, bidx)
        res.dispatches += 1

    chain_acc: list = []  # consecutive fusable cohorts awaiting one dispatch
    chain_lo = chain_hi = 0  # row-bucket band of the accumulating chain

    def flush_chain():
        nonlocal R, Mom
        if not chain_acc:
            return
        if len(chain_acc) == 1:
            ints, w = pack(chain_acc[0], _bucket(len(chain_acc[0]), M))
            R, Mom = step(R, Mom, dx, dy, ints, w)
        else:
            blen = len(chain_acc[0][0][5])
            B = chain_hi  # uniform bucket per chain (the band's max)
            L = _chain_bucket(len(chain_acc))
            ints_seq = np.zeros((L, B, 3 + blen), np.int32)  # pads: valid=0
            w_seq = np.zeros((L, B), np.float32)
            for l, c in enumerate(chain_acc):
                ints_seq[l], w_seq[l] = pack(c, B)
            R, Mom = chain_step(R, Mom, dx, dy, ints_seq, w_seq)
        res.dispatches += 1
        chain_acc.clear()

    def dispatch_burst(run):
        """One serial-chain dispatch over a pop-ordered event run (see
        ``_make_burst_body``)."""
        nonlocal R, Mom
        blen = len(run[0][5])
        L = _chain_bucket(len(run), _BURST_CAP)
        w = np.zeros(L, np.float32)
        if sr is not None:  # ps-serial: [actor, push, valid, batch...]
            ints = np.zeros((L, 3 + blen), np.int32)  # pads: valid=0 no-ops
            for l, e in enumerate(run):
                ints[l, 0] = e[1]
                ints[l, 1] = 1 if e[4] else 0
                ints[l, 2] = 1
                ints[l, 3:] = e[5]
                w[l] = e[3]
            R, Mom = burst_step(R, Mom, dx, dy, ints, w)
        else:  # gossip: one actor; [peer, valid, batch...]
            ints = np.zeros((L, 2 + blen), np.int32)
            for l, e in enumerate(run):
                ints[l, 0] = e[2] if e[4] else e[1]
                ints[l, 1] = 1
                ints[l, 2:] = e[5]
                w[l] = e[3]
            R, Mom = burst_step(R, Mom, dx, dy, np.int32(run[0][1]), ints, w)
        res.dispatches += 1

    def chain_in(cohort):
        """Feed one level into the band chain, flushing when it won't fit.

        A chain accepts a level while the row buckets stay within a 2x
        band (every level pads to the band's max, so the band bounds the
        wasted rows at ~1/2) — this fuses both the wide plateau at the head
        of a window and the tail of small levels, each into few dispatches,
        without padding tail levels up to head-size buckets.
        """
        nonlocal chain_lo, chain_hi
        B = _bucket(len(cohort), M)
        blen = len(cohort[0][5])
        if chain_acc and not (
            len(chain_acc) < _CHAIN_CAP
            and len(chain_acc[0][0][5]) == blen
            and max(chain_hi, B) <= 2 * min(chain_lo, B)
        ):
            flush_chain()
        if not chain_acc:
            chain_lo = chain_hi = B
        else:
            chain_lo, chain_hi = min(chain_lo, B), max(chain_hi, B)
        chain_acc.append(cohort)

    def execute_window(levels, window):
        """Dispatch one window.

        Levels are always counted/logged (the logical cohort structure is
        execution-independent).  Execution is fused three ways:

        * ps-serial + fusion — the serialized row makes the *whole stream*
          sequential, so the window executes as pop-ordered serial bursts
          (one scan carrying the PS row + momentum), broken only where a
          non-PS actor repeats (its second grad must re-read its own
          written row), the batch length changes, or ``_BURST_CAP``.
        * gossip + fusion — runs of >= _BURST_MIN consecutive singleton
          levels of one worker go through the single-row burst scan;
          everything else accumulates into band chains (``chain_in``).
        * fusion off — one dispatch per level.
        """
        nonlocal R, Mom
        for cohort in levels:
            res.cohorts += 1
            if cohort_log is not None:
                cohort_log.append(
                    [(e[6], e[1], e[2] if e[4] else None) for e in cohort]
                )
        if shard:
            # The sharded path has its own dispatch shape (full-M masked
            # rows on the mesh); fusion machinery stays on the dense path.
            for cohort in levels:
                dispatch_sharded(cohort)
            return
        if not fuse:
            for cohort in levels:
                ints, w = pack(cohort, _bucket(len(cohort), M))
                R, Mom = step(R, Mom, dx, dy, ints, w)
                res.dispatches += 1
            return
        if sr is not None:
            run: list = []
            actors: set[int] = set()
            for e in window:
                if run and (
                    len(run) >= _BURST_CAP
                    or len(e[5]) != len(run[0][5])
                    or (e[1] != sr and e[1] in actors)
                ):
                    dispatch_burst(run)
                    run, actors = [], set()
                run.append(e)
                if e[1] != sr:
                    actors.add(e[1])
            if run:
                dispatch_burst(run)
            return
        # Gossip: group levels into maximal single-actor singleton runs
        # (the busiest worker's sequential tail) vs the rest.  With
        # use_mix_kernel the cohort path mixes through kernels/ops.mix_rows
        # while bursts use the leaf rule — keep every dispatch on one rule
        # by skipping bursts there (band chains still fuse).
        burst_ok = not cfg.use_mix_kernel
        runs: list[list] = []
        for cohort in levels:
            if (
                len(cohort) == 1
                and runs
                and runs[-1][0] == "burst"
                and len(runs[-1][1]) < _BURST_CAP
                and runs[-1][1][-1][1] == cohort[0][1]
                and len(runs[-1][1][-1][5]) == len(cohort[0][5])
            ):
                runs[-1][1].append(cohort[0])
            elif len(cohort) == 1:
                runs.append(["burst", [cohort[0]]])
            else:
                runs.append(["normal", cohort])
        for kind, item in runs:
            if kind == "burst" and len(item) >= _BURST_MIN and burst_ok:
                flush_chain()  # preserve level order across dispatch paths
                dispatch_burst(item)
            elif kind == "burst":
                for e in item:  # short run: ride the band chain instead
                    chain_in([e])
            else:
                chain_in(item)
        flush_chain()

    while ev < total:
        # ---- scenario churn actions fire before the first event popping
        # at or after their time, between device dispatches ----
        if cursor is not None:
            for act in cursor.pop_due(heap.peek_time()):
                apply_action(act, active=active, reseed=reseed, rng=rng,
                             heap=heap, emas=emas, ema_beta=cfg.ema_beta)
        # ---- draw one window of events, stopping at the next boundary ----
        window = []
        while len(window) < window_cap and ev < total:
            if cursor is not None and heap.peek_time() >= cursor.next_time:
                break  # scenario boundary: flush before crossing it
            e = draw_event()
            window.append(e)
            if (monitor is not None and e[0] >= next_monitor) or e[6] % record_every == 0:
                break
        if not window:
            continue  # boundary was immediately due; actions now applied
        t_last, ev_last = window[-1][0], window[-1][6]

        # ---- execute the whole window, level by level (chains fused) ----
        execute_window(schedule_window(window), window)

        # ---- boundaries fire after the window, exactly as the reference
        # loop fires them after the boundary event (Monitor first, then the
        # periodic evaluation) ----
        if monitor is not None and t_last >= next_monitor:
            # The whole wake — failover, chaos, collect, step, publish —
            # is one shared function (scenarios/driver.monitor_boundary):
            # parity demands identical decisions, so both loops make them
            # through identical code at identical virtual times.
            pol = monitor_boundary(
                monitor, algo, state, link_model, emas, active, t_last,
                chaos=cfg.chaos,
            )
            if pol is not None:
                res.policy_updates += 1
                res.policy_log.append((t_last, pol.rho, pol.P.copy()))
            next_monitor += monitor.schedule_period
        if ev_last % record_every == 0:
            eval_now(t_last, ev_last)

    eval_now(t, ev)
    if monitor is not None and monitor.failover is not None:
        res.leader_log = list(monitor.failover.leader_log)
        res.skipped_refreshes = monitor.failover.n_skipped_refreshes
    res.engine = "batched"
    return res


# --------------------------------------------------------------------------
# Synchronous families: stacked round executor
# --------------------------------------------------------------------------


def _make_sync_round_body(algo: Algorithm, lr: float, mu: float):
    """One synchronous round on stacked trees: vmapped masked grad steps +
    the one-segment-mean group averaging (``reduce_groups_stacked``).

    Signature: (R, Mom, dx, dy, mask, gid, idx) -> (R, Mom) with R/Mom
    leaves (M, ...), ``idx`` (M, B) i32 per-worker batch indices, ``mask``
    (M, B) f32 marking real samples (per-worker batch sizes may differ when
    shards are smaller than cfg.batch_size), and ``gid`` (M,) i32 reduction
    group ids.
    """

    def masked_ce(params, x, y, mask):
        logits = _sim.mlp_apply(params, x)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
        return ((logz - gold) * mask).sum() / mask.sum()

    vgrad = jax.vmap(jax.value_and_grad(masked_ce))

    def body(R, Mom, dx, dy, mask, gid, idx):
        xb, yb = dx[idx], dy[idx]
        _, grads = vgrad(R, xb, yb, mask)
        Mom = tree_map(lambda m_, g: mu * m_ + g, Mom, grads)
        x_half = tree_map(lambda p, m_: p - lr * m_, R, Mom)
        R = algo.reduce_groups_stacked(x_half, gid)
        return R, Mom

    return body


def _sync_steps_for(algo: Algorithm, lr: float, mu: float):
    stacked = type(algo).reduce_groups_stacked is Algorithm.reduce_groups_stacked
    key = (algo.cache_token(), "sync", float(lr), float(mu),
           stacked or type(algo).__qualname__)
    entry = _STEP_CACHE.get(key)
    if entry is None:
        body = _make_sync_round_body(algo, lr, mu)
        step = jax.jit(body, donate_argnums=(0, 1))

        def chain_body(R, Mom, dx, dy, mask, gid_seq, idx_seq):
            def f(carry, xs):
                gid, idx = xs
                return body(carry[0], carry[1], dx, dy, mask, gid, idx), None

            carry, _ = jax.lax.scan(f, (R, Mom), (gid_seq, idx_seq))
            return carry

        chain = jax.jit(chain_body, donate_argnums=(0, 1))
        entry = (step, chain)
        _STEP_CACHE[key] = entry
    return entry


def run_batched_sync(
    algo: Algorithm,
    cfg,
    state,
    rng: np.random.Generator,
    p0,
    link_model,
    data_x: np.ndarray,
    data_y: np.ndarray,
    part_idx,
    eval_x: np.ndarray,
    eval_y: np.ndarray,
    record_every: int,
    res,
):
    """Round-based strategies on stacked trees; mutates and returns ``res``.

    Host-side machinery is drawn in exactly the reference sync loop's order
    (``select_groups`` -> ``round_timing`` -> per-worker batch draws), so
    ``times``/``comm_time``/``compute_time`` are bit-identical; only the
    device math is reassociated (vmapped grads, segment means).  Rounds
    between record boundaries are scan-fused into one dispatch carrying the
    donated (R, Mom) when ``cfg.fuse_chains`` is on.
    """
    M = cfg.n_workers
    rounds = cfg.total_events // M
    fuse = getattr(cfg, "fuse_chains", True)

    R = tree_map(lambda l: jnp.array(jnp.broadcast_to(l[None], (M,) + l.shape)), p0)
    Mom = tree_map(lambda l: jnp.zeros((M,) + l.shape, l.dtype), p0)
    step, chain_step = _sync_steps_for(algo, cfg.lr, cfg.momentum)

    # Scenario machinery: boundaries break the scan-fused round blocks so a
    # rejoin reseed lands between dispatches, at the same round as the
    # reference loop; link-state changes need no action (round_timing draws
    # from the link model at each round's start time on both engines).
    scn = link_model.compiled_scenario
    cursor = ScenarioCursor(scn) if scn is not None else None
    active = set(range(M))

    def reseed(w, src):
        nonlocal R, Mom
        R, Mom = reseed_row(R, Mom, w, src)

    bsz = [min(cfg.batch_size, len(part_idx[i])) for i in range(M)]
    Bmax = max(bsz)
    mask = np.zeros((M, Bmax), np.float32)
    for i in range(M):
        mask[i, : bsz[i]] = 1.0
    maskj = jnp.asarray(mask)

    # Block batch draw: ``rng.choice(part, size=k)`` is ``part[rng.integers(0,
    # len(part), k)]`` bit-for-bit, and one ``integers`` call fills its output
    # in C order drawing per element exactly as consecutive same-bound calls
    # do — so consecutive workers with equal (population, batch) sizes
    # collapse into one host rng call per round instead of M (with uniform
    # shards that is a single call).  The sync parity suite pins times/RNG
    # equality with the reference loop's per-worker draws.
    pops = [len(part_idx[i]) for i in range(M)]
    runs = []
    i0 = 0
    for i in range(1, M + 1):
        if i == M or pops[i] != pops[i0] or bsz[i] != bsz[i0]:
            runs.append((i0, i, pops[i0], bsz[i0]))
            i0 = i
    run_parts = [
        np.stack([np.asarray(part_idx[i]) for i in range(a, b)])
        for a, b, _, _ in runs
    ]

    ex, ey = jnp.asarray(eval_x), jnp.asarray(eval_y)
    dx, dy = jnp.asarray(data_x), jnp.asarray(data_y)

    def eval_now(t, ev):
        loss, acc = _eval_stacked(R, ex, ey)
        res.times.append(t)
        res.losses.append(float(loss))
        res.accs.append(float(acc))
        res.events.append(ev)

    every = max(1, record_every // M)
    t = 0.0
    r = 0
    while r < rounds:
        if cursor is not None:
            for act in cursor.pop_due(t):
                apply_action(act, active=active, reseed=reseed)
        # ---- draw a block of rounds, ending at the next record boundary,
        # consuming every host rng in reference order ----
        gids, idxs = [], []
        fire = False
        while r < rounds:
            if cursor is not None and cursor.next_time <= t:
                break  # scenario boundary: flush the block before crossing
            groups = algo.select_groups(state, rng)
            timing = _sim.traced_round_timing(
                algo, state, cfg, link_model, groups, t, res
            )
            t += timing.duration
            res.comm_time += timing.comm
            res.compute_time += timing.compute
            gid = np.arange(M, dtype=np.int32)
            for grp in groups:
                if len(grp) >= 2:
                    gid[grp] = min(grp)
            idx = np.zeros((M, Bmax), np.int32)
            for (a, b_, pop, B), parts in zip(runs, run_parts):
                draws = rng.integers(0, pop, size=(b_ - a, B))
                idx[a:b_, :B] = parts[
                    np.arange(b_ - a)[:, None], draws
                ]
            gids.append(gid)
            idxs.append(idx)
            fire = r % every == 0
            r += 1
            if fire:
                break

        if not gids:
            continue  # boundary was immediately due; actions now applied
        # ---- execute the block: one dispatch per block (scan over rounds),
        # or per round with fusion off ----
        if len(gids) > 1 and fuse:
            R, Mom = chain_step(R, Mom, dx, dy, maskj,
                                jnp.asarray(np.stack(gids)),
                                jnp.asarray(np.stack(idxs)))
            res.dispatches += 1
        else:
            for gid, idx in zip(gids, idxs):
                R, Mom = step(R, Mom, dx, dy, maskj,
                              jnp.asarray(gid), jnp.asarray(idx))
                res.dispatches += 1
        res.cohorts += len(gids)

        if fire:
            eval_now(t, r * M)
    eval_now(t, rounds * M)
    res.engine = "batched"
    return res
