"""Training runtime: NetMax trainer, checkpointing, elasticity, simulator."""
