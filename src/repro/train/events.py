"""Lazy-invalidation event queue shared by both async engine loops.

Both the reference loop (train/simulator.py) and the batched engine
(train/engine.py) schedule worker events on a binary heap of ``(time,
worker)`` entries, one live entry per worker.  Scenario churn used to
*eagerly* prune a departing worker's entry — an O(M) list rebuild plus
re-heapify per leave, which made the ``federated_cohorts`` preset's t=0
leave storm O(M^2) at boot (ROADMAP "Scenario depth, round 3").

``EventHeap`` keeps the heap untouched on a leave and marks the worker's
entry dead instead (O(1)); dead entries are discarded when they surface at
the top (``_prune``), so the total cost of a leave storm is O(M log M) —
the pops the eager path was paying anyway.  Event *order* is unchanged:
popping-and-skipping a dead entry consumes no RNG and advances no clock,
so the sequence of live events (and every ``peek_time`` a loop uses to
gate scenario/boundary decisions) is identical to the eager-prune
behaviour — tests/test_scenarios.py pins the equivalence on randomized
push/invalidate/pop schedules, and the engine-parity suites pin it end to
end through churn timelines.

Liveness is *entry identity*, not ``(time, worker)`` value: a worker that
leaves and rejoins has a fresh live entry while its pre-leave entry may
still be buried in the heap, and the two could even carry equal times.
``_live`` maps each worker to the exact tuple object that is current, so
the stale twin is recognized (``is``) and dropped when it surfaces.
"""

from __future__ import annotations

import heapq
import math


class EventHeap:
    """Min-heap of ``(time, worker)`` with O(1) worker invalidation."""

    __slots__ = ("_heap", "_live")

    def __init__(self):
        self._heap: list[tuple[float, int]] = []
        self._live: dict[int, tuple[float, int]] = {}

    def push(self, t: float, i: int) -> None:
        """Schedule worker ``i``'s next event at time ``t`` (the worker's
        previous entry, if any, becomes stale and is skipped on surfacing)."""
        e = (t, i)
        self._live[i] = e
        heapq.heappush(self._heap, e)

    def invalidate(self, i: int) -> None:
        """Drop worker ``i``'s scheduled event (churn leave).  O(1): the
        heap entry stays put and is discarded when it reaches the top."""
        self._live.pop(i, None)

    def _prune(self) -> None:
        h = self._heap
        while h and self._live.get(h[0][1]) is not h[0]:
            heapq.heappop(h)

    def peek_time(self) -> float:
        """Time of the next *live* event (inf when none are scheduled)."""
        self._prune()
        return self._heap[0][0] if self._heap else math.inf

    def pop(self) -> tuple[float, int]:
        """Pop the next live event; raises IndexError when empty."""
        self._prune()
        e = heapq.heappop(self._heap)
        del self._live[e[1]]
        return e

    def __len__(self) -> int:  # live entries only
        return len(self._live)

    def __bool__(self) -> bool:
        return bool(self._live)
