"""Per-architecture configs (one module per assigned arch) + registry."""

from repro.configs.base import (  # noqa: F401
    SHAPES,
    ArchConfig,
    MambaConfig,
    MoEConfig,
    RWKVConfig,
    ShapeSpec,
    all_archs,
    get_arch,
    register,
)
