"""Whisper-small: 12L enc + 12L dec, conv frontend STUB.

[arXiv:2212.04356; unverified].  input_specs() provides precomputed frame
embeddings; decode shapes exercise the decoder with a mechanically sized
self-attention KV cache.
"""

from repro.configs.base import ArchConfig, register

CFG = register(
    ArchConfig(
        name="whisper-small",
        family="audio",
        n_layers=12,
        d_model=768,
        n_heads=12,
        n_kv_heads=12,
        d_ff=3072,
        vocab_size=51865,
        head_dim=64,
        activation="gelu",
        norm="layernorm",
        n_enc_layers=12,
        enc_seq_len=1500,
        worker_axes=("pod", "data"),
        notes="Enc-dec; 12 heads % 16 != 0 -> seq-parallel attention fallback.",
    )
)
