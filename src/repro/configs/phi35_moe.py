"""Phi-3.5-MoE-instruct: 42B total / 6.6B active, 16 experts top-2.

[hf:microsoft/Phi-3.5-MoE-instruct].
"""

from repro.configs.base import ArchConfig, MoEConfig, register

CFG = register(
    ArchConfig(
        name="phi3.5-moe-42b-a6.6b",
        family="moe",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=6400,
        vocab_size=32064,
        head_dim=128,
        moe=MoEConfig(n_experts=16, top_k=2),
        rope_theta=10000.0,
        worker_axes=("pod",),
        fsdp=True,
        microbatches=8,
        notes="All layers MoE; EP=16 over model axis; replica too big for a 16-chip slice with fp32 optimizer state -> pod-level workers + FSDP.",
    )
)
