"""Jamba-v0.1 52B: Mamba+attention 1:7 interleave, MoE 16e top-2 every 2.

[arXiv:2403.19887; hf].  52B params -> worker_axes=("pod",) with FSDP+TP
inside the worker.  Serves long_500k (mamba state + 4 attention layers).
"""

from repro.configs.base import ArchConfig, MambaConfig, MoEConfig, register

CFG = register(
    ArchConfig(
        name="jamba-v0.1-52b",
        family="hybrid",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14336,
        vocab_size=65536,
        head_dim=128,
        moe=MoEConfig(n_experts=16, top_k=2, layout="every_2"),
        mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
        attn_period=8,
        worker_axes=("pod",),
        fsdp=True,
        microbatches=8,
        notes="1 attention layer per 8 (4 of 32); MoE on even layers.",
    )
)
