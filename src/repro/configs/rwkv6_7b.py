"""RWKV-6 'Finch' 7B: attention-free, data-dependent decay.

[arXiv:2404.05892; hf].  Serves long_500k (O(1) recurrent state per token).
"""

from repro.configs.base import ArchConfig, RWKVConfig, register

CFG = register(
    ArchConfig(
        name="rwkv6-7b",
        family="ssm",
        n_layers=32,
        d_model=4096,
        n_heads=64,   # d_model / rwkv head_dim
        n_kv_heads=64,
        d_ff=14336,
        vocab_size=65536,
        head_dim=64,
        rwkv=RWKVConfig(head_dim=64, decay_lora=64),
        worker_axes=("pod", "data"),
        microbatches=4,
        notes="Attention-free: NetMax applies unchanged (protocol is model-agnostic).",
    )
)
