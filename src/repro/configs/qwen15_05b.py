"""Qwen1.5-0.5B: MHA with QKV bias.  [hf:Qwen/Qwen1.5-0.5B]."""

from repro.configs.base import ArchConfig, register

CFG = register(
    ArchConfig(
        name="qwen1.5-0.5b",
        family="dense",
        n_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        d_ff=2816,
        vocab_size=151936,
        head_dim=64,
        qkv_bias=True,
        tie_embeddings=True,
        rope_theta=1e6,
        worker_axes=("pod", "data"),
        microbatches=2,
    )
)
