"""Architecture + shape configuration system.

Every assigned architecture is an ``ArchConfig`` registered under its id;
``--arch <id>`` in the launchers resolves through ``get_arch``.  Input
shapes are global (seq_len x global_batch) and map to one of three lowered
programs: train_step / serve_prefill / serve_step (DESIGN.md §5).
"""

from __future__ import annotations

from dataclasses import dataclass, replace

# ---------------------------------------------------------------------------
# Shapes (assigned): seq_len x global_batch, and which program they lower.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Architectures
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    # Layers with MoE MLPs; "all" or "every_2" (jamba-style alternation).
    layout: str = "all"


@dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int | None = None  # default d_model // 16


@dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    decay_lora: int = 64  # rank of the data-dependent decay LoRA (Finch)


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    activation: str = "swiglu"  # swiglu | gelu
    moe: MoEConfig | None = None
    mamba: MambaConfig | None = None
    rwkv: RWKVConfig | None = None
    # hybrid (jamba): one attention layer per `attn_period` layers, rest mamba.
    attn_period: int = 0
    # encoder-decoder (whisper): encoder layers; n_layers = decoder layers.
    n_enc_layers: int = 0
    enc_seq_len: int = 1500  # frozen encoder frames (audio stub)
    # vlm: number of vision-stub tokens prepended to the text sequence.
    n_vis_tokens: int = 0
    # --- distribution hints -------------------------------------------------
    # Mesh axes that enumerate NetMax workers ("data" => M=16/32; "pod" =>
    # M=#pods with FSDP+TP inside — for models too big to replicate per-row).
    worker_axes: tuple = ("pod", "data")
    fsdp: bool = False
    # --- TP head padding (§Perf hillclimb) ------------------------------------
    # Extra zero-initialized q / kv heads so head counts divide the TP degree
    # (inert at init: padded q rows are zero AND their wo rows are zero, so
    # they contribute exactly nothing; they add ~pad/H flops but unlock
    # 16-way TP instead of replicated attention).
    pad_heads: int = 0
    pad_kv_heads: int = 0
    # --- numerics ------------------------------------------------------------
    dtype: str = "bfloat16"
    remat: bool = True
    # Gradient-accumulation microbatches per round (bounds saved-activation
    # memory: peak ~ (b/microbatches) * S * d_model * n_layers * 2B).
    microbatches: int = 1
    notes: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_heads_eff(self) -> int:
        return self.n_heads + self.pad_heads

    @property
    def n_kv_heads_eff(self) -> int:
        return self.n_kv_heads + self.pad_kv_heads

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch serve 500k-token contexts?  SSM/hybrid only."""
        return self.family in ("ssm", "hybrid")

    def supports(self, shape: ShapeSpec) -> bool:
        if shape.name == "long_500k":
            return self.sub_quadratic
        return True

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw = dict(
            n_layers=2,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            d_ff=128,
            vocab_size=256,
            head_dim=16,
            dtype="float32",
            remat=False,
        )
        if self.moe is not None:
            # capacity_factor 2.0: no token drops at smoke-test sizes, so
            # decode matches teacher-forced forward exactly.
            kw["moe"] = replace(
                self.moe, n_experts=4, top_k=min(self.moe.top_k, 2), capacity_factor=2.0
            )
        if self.mamba is not None:
            kw["mamba"] = MambaConfig(d_state=4, d_conv=4, expand=2, dt_rank=8)
        if self.rwkv is not None:
            kw["rwkv"] = RWKVConfig(head_dim=16, decay_lora=8)
        if self.attn_period:
            kw["attn_period"] = 2
            kw["n_layers"] = 4
        if self.n_enc_layers:
            kw["n_enc_layers"] = 2
            kw["enc_seq_len"] = 32
        if self.n_vis_tokens:
            kw["n_vis_tokens"] = 8
        return replace(self, **kw)


_REGISTRY: dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    if name not in _REGISTRY:
        # Import the per-arch modules lazily so `configs.base` has no deps.
        from repro import configs as _c  # noqa: F401

        _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def all_archs() -> dict[str, ArchConfig]:
    _load_all()
    return dict(_REGISTRY)


_LOADED = False


def _load_all():
    global _LOADED
    if _LOADED:
        return
    import importlib

    for mod in (
        "internvl2_1b",
        "phi35_moe",
        "llama4_maverick",
        "rwkv6_7b",
        "jamba_v01",
        "starcoder2_3b",
        "qwen15_05b",
        "tinyllama_11b",
        "stablelm_12b",
        "whisper_small",
        "netmax_paper",
    ):
        importlib.import_module(f"repro.configs.{mod}")
    _LOADED = True
