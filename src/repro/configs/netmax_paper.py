"""The paper's own experimental configuration (SSV).

NetMax SSV trains ResNet18/VGG19/MobileNet on CIFAR - CNNs on GPU boxes.
The algorithmic reproduction (speedups, ablations, accuracy parity) runs in
the event-driven simulator on small pure-JAX models; this module records the
paper's protocol hyperparameters used by benchmarks/run.py.
"""

from dataclasses import dataclass


@dataclass(frozen=True)
class PaperConfig:
    n_workers: int = 8
    batch_size: int = 128
    momentum: float = 0.9
    weight_decay: float = 1e-4
    lr0: float = 0.1
    schedule_period_s: float = 120.0  # T_s = 2 minutes
    ema_beta: float = 0.5
    slow_link_range: tuple = (2.0, 100.0)
    slow_link_interval_s: float = 300.0
    policy_K: int = 10
    policy_R: int = 10
    eps: float = 1e-2


PAPER = PaperConfig()
