"""InternVL2-1B backbone: InternLM2-1B LM (GQA kv=2) + ViT patch stub.

[arXiv:2404.16821; hf].  The vision frontend is a STUB: input_specs()
supplies precomputed patch embeddings (n_vis_tokens x d_model).
"""

from repro.configs.base import ArchConfig, register

CFG = register(
    ArchConfig(
        name="internvl2-1b",
        family="vlm",
        n_layers=24,
        d_model=896,
        n_heads=14,
        n_kv_heads=2,
        d_ff=4864,
        vocab_size=151655,
        head_dim=64,
        n_vis_tokens=256,
        tie_embeddings=True,
        rope_theta=1e6,
        worker_axes=("pod", "data"),
        notes="InternViT frontend stubbed; backbone LM trains under NetMax-DP.",
    )
)
