"""TinyLlama-1.1B: llama2-arch small, GQA kv=4.  [arXiv:2401.02385; hf]."""

from repro.configs.base import ArchConfig, register

CFG = register(
    ArchConfig(
        name="tinyllama-1.1b",
        family="dense",
        n_layers=22,
        d_model=2048,
        n_heads=32,
        n_kv_heads=4,
        d_ff=5632,
        vocab_size=32000,
        head_dim=64,
        rope_theta=10000.0,
        worker_axes=("pod", "data"),
        microbatches=2,
        notes="Used (reduced) by the end-to-end ~100M training example.",
    )
)
