"""StarCoder2-3B: GQA kv=2, RoPE.  [arXiv:2402.19173; hf]."""

from repro.configs.base import ArchConfig, register

CFG = register(
    ArchConfig(
        name="starcoder2-3b",
        family="dense",
        n_layers=30,
        d_model=3072,
        n_heads=24,
        n_kv_heads=2,
        d_ff=12288,
        vocab_size=49152,
        head_dim=128,
        activation="gelu",
        norm="layernorm",
        rope_theta=999999.4,
        worker_axes=("pod", "data"),
        microbatches=4,
        notes="24 heads % 16 != 0 -> seq-parallel attention fallback.",
    )
)
