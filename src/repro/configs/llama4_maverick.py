"""Llama-4 Maverick: 400B total / 17B active, 128 experts top-1.

[hf:meta-llama/Llama-4-Scout-17B-16E; unverified].  Too large to replicate
per data-row: worker_axes=("pod",) with FSDP(data) x TP(model) inside each
worker (DESIGN.md SS2 worker granularity).
"""

from repro.configs.base import ArchConfig, MoEConfig, register

CFG = register(
    ArchConfig(
        name="llama4-maverick-400b-a17b",
        family="moe",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        d_ff=8192,
        vocab_size=202048,
        head_dim=128,
        moe=MoEConfig(n_experts=128, top_k=1, layout="every_2"),
        rope_theta=500000.0,
        worker_axes=("pod",),
        fsdp=True,
        microbatches=16,
        notes="MoE interleaved every other layer (how Maverick reaches 400B total); 40 heads % 16 != 0 -> attention TP falls back to replication (hillclimbed via head padding in SSPerf).",
    )
)
