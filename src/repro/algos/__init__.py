"""Pluggable communication strategies (DESIGN.md §1).

Importing this package populates the registry with the paper's seven
strategies plus the beyond-paper ``netmax-topk``:

    from repro.algos import get_algorithm, list_algorithms
    algo = get_algorithm("netmax")
"""

from repro.algos.base import (
    Algorithm,
    AlgoState,
    Timing,
    get_algorithm,
    list_algorithms,
    mean_params,
    register,
)

# Importing the strategy modules registers them.
from repro.algos import collective as _collective  # noqa: F401
from repro.algos import netmax as _netmax  # noqa: F401
from repro.algos import netmax_topk as _netmax_topk  # noqa: F401
from repro.algos import ps as _ps  # noqa: F401

__all__ = [
    "Algorithm",
    "AlgoState",
    "Timing",
    "get_algorithm",
    "list_algorithms",
    "mean_params",
    "register",
]
