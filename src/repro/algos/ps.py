"""Parameter-server baselines (paper §V / Fig. 14).

All PS traffic funnels through one node ("the training is constrained by
the network capacity at the parameter server"): each additional concurrent
worker inflates the PS link time by ``cfg.ps_congestion``.

  ps-sync   barrier at the PS every round (synchronous)
  ps-async  per-worker asynchronous push/pull
"""

from __future__ import annotations

from repro.algos.base import (
    Algorithm,
    AlgoState,
    Timing,
    global_mean_grads,
    register,
)


def _ps_congestion(cfg, M: int) -> float:
    return 1.0 + getattr(cfg, "ps_congestion", 0.4) * (M - 2)


@register("ps-sync")
class PSSync(Algorithm):
    """Synchronous parameter server: every worker exchanges with the PS,
    barrier, global average (mathematically an allreduce through a star)."""

    family = "ps"
    synchronous = True
    reports_ema = False

    def select_groups(self, state: AlgoState, rng):
        return [list(range(state.M))]

    def round_timing(self, state, cfg, link, groups, t):
        M = state.M
        ps = getattr(cfg, "ps_node", 0)
        comm = max(
            link.iteration_time(i, ps, now=t) for i in range(M) if i != ps
        ) * _ps_congestion(cfg, M)
        comp = link.compute_time
        return Timing(duration=comp + comm, comm=comm, compute=comp)

    def transform_grads(self, grads, M):
        return global_mean_grads(grads)


@register("ps-async")
class PSAsync(Algorithm):
    """Asynchronous parameter server: each event, worker i pushes its fresh
    replica to the PS; the PS absorbs and returns the running average.

    ``apply_comm`` mutates the *peer* (PS) replica, so pushes sharing the PS
    are never causally independent and the default gossip cohort step cannot
    replay them.  The batched engine instead uses the ``"ps-serial"``
    variant: a cohort's grad steps run as one stacked vmapped call, and the
    PS running average is folded as a serialized chain over the cohort's
    ``x_half`` rows in exact pop order inside the same dispatch
    (``s <- s + w (x_k - s)``), which is bit-for-bit the reference's
    event-at-a-time ``mix`` recurrence (DESIGN.md §12)."""

    family = "ps"
    synchronous = False
    reports_ema = False  # the PS star has no per-link policy to learn

    @property
    def supports_trainer(self) -> bool:
        return False  # per-worker async push/pull has no lockstep SPMD form

    @property
    def batched_variant(self) -> str:
        return "ps-serial"

    def serial_row(self, state: AlgoState) -> int:
        return state.extras.get("ps_node", 0)

    def would_communicate(self, state: AlgoState, i, m) -> bool:
        return m is not None  # every non-PS worker talks to the PS

    def select_peer(self, state: AlgoState, i: int, rng):
        ps = state.extras.get("ps_node", 0)
        return ps if i != ps else None

    def init_state(self, cfg, M):
        state = super().init_state(cfg, M)
        state.extras["ps_node"] = getattr(cfg, "ps_node", 0)
        return state

    def apply_comm(self, state, cfg, replicas, i, m, x_half):
        if m is None:  # the PS node itself: local step only
            replicas[i] = x_half
            return False
        # Push/pull with the PS: PS absorbs then returns the average.
        mean_p = self.mix(replicas[m], x_half, 0.5)
        replicas[m] = mean_p
        replicas[i] = mean_p
        return True

    def event_timing(self, state, cfg, link, i, m, communicated, t):
        comp = link.compute_time
        if not communicated:
            return Timing(duration=comp, comm=0.0, compute=comp)
        # The PS link carries all M-1 workers' traffic (congestion).  The
        # raw (pre-congestion) link time rides along in ``net`` so traced
        # runs replay bit-exactly: the seam serves it back and this very
        # multiplier re-applies (repro.trace.replay).
        raw = link.iteration_time(i, m, now=t)
        dur = raw * _ps_congestion(cfg, state.M)
        return Timing(duration=dur, comm=max(0.0, dur - comp), compute=comp,
                      net=raw)
