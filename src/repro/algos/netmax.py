"""Gossip-family strategies: NetMax (paper Alg. 2/3) and AD-PSGD baselines.

  netmax      adaptive P from Alg. 3; mix weight alpha*rho*gamma_{i,m}
  adpsgd      uniform neighbor, fixed averaging weight 1/2 (Lian et al., 2018)
  adpsgd+mon  AD-PSGD retrofitted with Monitor-optimized probabilities
              (paper §V-H / Fig. 15)
"""

from __future__ import annotations

from repro.algos.base import Algorithm, AlgoState, register


class GossipAlgorithm(Algorithm):
    """Shared event-driven gossip behavior: neighbor ~ P[i], pull + mix.

    The whole family is pull-only (``apply_comm`` touches replicas[i] alone),
    so it inherits ``supports_batched = True`` and runs on the vectorized
    cohort engine (train/engine.py) as well as the reference event loop.
    """

    family = "gossip"
    synchronous = False
    reports_ema = True

    def select_peer(self, state: AlgoState, i: int, rng) -> int:
        # Cached-CDF draw. ``rng.choice(M, p=row)`` recomputes the row's
        # cumsum on every event — O(M) per draw, the dominant host cost at
        # fleet scale. P is only ever rebound (never mutated in place), so
        # the per-row CDFs stay valid until ``state.policy_version``
        # changes — the counter AlgoState bumps on every rebind of P.
        # (Keying on ``id(state.P)`` is unsound: a freed policy matrix's
        # address can be reused by a later allocation, serving stale CDFs.)
        # The draw mirrors Generator.choice's internals exactly (cumsum,
        # normalize by the last entry, searchsorted(random(), 'right')),
        # consuming one uniform — bit-identical to the rng.choice path.
        pid, cdfs = state.extras.get("_peer_cdf", (None, None))
        if pid != state.policy_version:
            cdfs = {}
            state.extras["_peer_cdf"] = (state.policy_version, cdfs)
        cdf = cdfs.get(i)
        if cdf is None:
            row = state.P[i] / state.P[i].sum()
            cdf = row.cumsum()
            cdf /= cdf[-1]
            cdfs[i] = cdf
        return int(cdf.searchsorted(rng.random(), side="right"))


@register("netmax")
class NetMax(GossipAlgorithm):
    """Paper Algorithm 2: adaptive peer selection + gamma-weighted mixing."""

    def wants_monitor(self, cfg) -> bool:
        return not getattr(cfg, "uniform_policy", False)

    def on_policy(self, state, pol):
        super().on_policy(state, pol)
        state.rho = pol.rho  # NetMax also adopts the Alg.-3 consensus step

    def mix_weight(self, state, cfg, i, m):
        if not getattr(cfg, "adaptive_weight", True):
            return 0.5
        d = state.d
        gamma = (d[i, m] + d[m, i]) / (2 * state.P[i, m])
        return min(cfg.lr * state.rho_of(i) * gamma, 0.9)


@register("adpsgd")
class AdPsgd(GossipAlgorithm):
    """Lian et al. AD-PSGD: uniform neighbor, fixed 1/2 averaging."""

    def mix_weight(self, state, cfg, i, m):
        return 0.5


@register("adpsgd+mon")
class AdPsgdMonitored(AdPsgd):
    """AD-PSGD with Monitor-optimized selection probabilities (paper §V-H):
    P adapts to the network, the averaging weight stays 1/2."""

    def wants_monitor(self, cfg) -> bool:
        return not getattr(cfg, "uniform_policy", False)
