"""The ``Algorithm`` protocol + registry (DESIGN.md §1).

One communication strategy = one ``Algorithm`` subclass owning its three
concerns:

* **peer/group selection** — host-side, numpy RNG: which neighbor a worker
  pulls from (async families) or how workers partition into reduction groups
  (synchronous families).
* **mixing semantics** — pure JAX: how pulled parameters fold into the local
  replica.  The same leaf-level rule serves both the event simulator's
  per-replica path (``mix``) and the SPMD trainer's stacked path
  (``stacked_round`` / ``mix_stacked``), which is what the parity tests pin.
* **timing semantics** — the per-event (or per-round) duration model:
  congestion, barriers, compute/communication overlap.

The event-driven simulator (train/simulator.py) and the SPMD trainer
(train/trainer.py) are thin drivers over this protocol; new strategies
(e.g. sparsified pulls, SAPS-style) register themselves and ride both
substrates plus the benchmark harness for free:

    @register("my-algo")
    class MyAlgo(Algorithm):
        ...

    algo = get_algorithm("my-algo")
"""

from __future__ import annotations

import abc
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------

_REGISTRY: dict[str, type["Algorithm"]] = {}


def register(name: str):
    """Class decorator: ``@register("netmax")`` adds the class to the registry."""

    def deco(cls):
        cls.name = name
        _REGISTRY[name] = cls
        return cls

    return deco


def get_algorithm(name: "str | Algorithm", **kwargs) -> "Algorithm":
    """Instantiate a registered algorithm by name (kwargs -> constructor).

    An Algorithm instance passes through unchanged — this is the single
    dispatch point for "name or instance" (SimConfig.algorithm etc.).
    """
    if isinstance(name, Algorithm):
        assert not kwargs, "kwargs only apply when constructing by name"
        return name
    try:
        cls = _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown algorithm {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None
    return cls(**kwargs)


def list_algorithms() -> list[str]:
    return sorted(_REGISTRY)


# --------------------------------------------------------------------------
# Shared state / timing records
# --------------------------------------------------------------------------


@dataclass
class AlgoState:
    """Host-side mutable state the event loop shares with the algorithm."""

    M: int
    d: np.ndarray  # connectivity mask (M, M), 0/1, zero diagonal
    P: np.ndarray  # communication policy matrix (rows sum to 1 on edges)
    rho: float  # consensus step size (paper Alg. 3)
    extras: dict = field(default_factory=dict)
    # Per-worker consensus step (set only by partition-aware policy
    # publishing, scenarios/driver.publish_policy): workers a home-pinned
    # Monitor could not reach keep their stale rho while reachable workers
    # adopt the fresh one.  None = everyone shares the scalar ``rho``.
    rho_vec: np.ndarray | None = None
    # Monotonic publish counter: bumped automatically on every rebind of
    # ``P`` (policy publish, partition-aware partial publish, tests).  This
    # is the cache key for anything derived from P — ``id(state.P)`` is NOT
    # safe: a freed policy matrix's address can be reused by a later
    # allocation, silently serving stale derived state (the gossip
    # peer-draw CDF cache hit exactly that).  P is never mutated in place
    # by the engines, so "version changed iff P was rebound" holds.
    policy_version: int = 0

    def __setattr__(self, name, value):
        if name == "P":
            object.__setattr__(
                self, "policy_version",
                getattr(self, "policy_version", -1) + 1,
            )
        object.__setattr__(self, name, value)

    def rho_of(self, i: int) -> float:
        """Worker ``i``'s consensus step (stale-policy aware)."""
        if self.rho_vec is None:
            return self.rho
        return float(self.rho_vec[i])


@dataclass
class Timing:
    """Duration model output for one event (async) or one round (sync).

    ``net`` carries the *raw* link time the event drew — the value
    ``link.iteration_time`` returned, before any strategy multiplier
    (ps-async congestion, netmax-topk wire ratio) is applied on top.
    Traced runs record it per async event so trace replay can serve it
    back through the ``LinkTimeModel.time_source`` seam and let
    ``event_timing`` re-apply the multipliers deterministically — that is
    what makes replay bit-exact for all strategies, not just the
    unit-multiplier gossip family (repro.trace.replay).  None for events
    that never drew a link time (local steps, sync rounds).
    """

    duration: float
    comm: float = 0.0
    compute: float = 0.0
    net: float | None = None


def uniform_state(cfg, M: int) -> AlgoState:
    """Fully-connected uniform policy + the conservative initial rho.

    Initial rho keeps w = alpha*rho*gamma <= 0.5 under the uniform policy
    (gamma = M-1); a Monitor's Alg.-3 rho replaces it on first refresh.
    """
    d = np.ones((M, M)) - np.eye(M)
    P = np.where(d > 0, 1.0 / (M - 1), 0.0)
    rho = getattr(cfg, "rho", None)
    if rho is None:
        rho = 0.5 / (2 * cfg.lr * max(M - 1, 1))
    return AlgoState(M=M, d=d, P=P, rho=rho)


def guard_policy_rows(P: np.ndarray, d: np.ndarray) -> np.ndarray:
    """Keep every row a valid sampling distribution (fallback: uniform)."""
    P = P.copy()
    bad = P.sum(axis=1) <= 0
    M = P.shape[0]
    P[bad] = np.where(d[bad] > 0, 1.0 / max(M - 1, 1), 0.0)
    return P


# --------------------------------------------------------------------------
# Protocol
# --------------------------------------------------------------------------


class Algorithm(abc.ABC):
    """One pluggable communication strategy; see module docstring."""

    name: str = "?"
    # gossip  — async pairwise pulls (netmax / adpsgd family)
    # collective — synchronous (partial-)allreduce rounds
    # ps      — parameter-server star
    family: str = "gossip"
    synchronous: bool = False  # round-based barrier loop vs event-driven
    reports_ema: bool = True  # workers feed IterationTimeEMA (Alg. 2 l.19-22)

    @property
    def supports_batched(self) -> bool:
        """Whether the batched engine (train/engine.py) can execute this
        strategy.  Decided from *capabilities*, not family names:

        * synchronous strategies batch whenever their group averaging is the
          default ``reduce_groups`` (it has a one-segment-mean stacked form,
          ``reduce_groups_stacked``, so every round is a single dispatch);
        * asynchronous strategies batch when ``apply_comm`` is the default
          pull+mix (a cohort of causally-independent events replays as one
          stacked vmapped call), or when they declare a non-default
          ``batched_variant`` describing their fused-cohort semantics
          (e.g. ps-async's serialized-PS-row formulation).

        A strategy with an exotic ``apply_comm``/``reduce_groups`` override
        and no batched variant stays on the reference engine."""
        if self.synchronous:
            # The two reduction forms must be a consistent pair: both
            # default (the segment-mean stacked form reproduces the default
            # mean exactly) or both overridden (the strategy vouches for
            # its own pair).  Overriding only one would let the engines
            # silently diverge — route that to the reference loop.
            default_ref = type(self).reduce_groups is Algorithm.reduce_groups
            default_stacked = (
                type(self).reduce_groups_stacked
                is Algorithm.reduce_groups_stacked
            )
            return default_ref == default_stacked
        return (
            type(self).apply_comm is Algorithm.apply_comm
            or self.batched_variant != "gossip"
        )

    @property
    def batched_variant(self) -> str:
        """Which fused cohort step the batched engine builds for async
        strategies: ``"gossip"`` (gather pre-cohort peer rows, pull + mix)
        or ``"ps-serial"`` (every communicating event pushes into one
        serialized row — the PS — folded in pop order inside the dispatch;
        see ``serial_row``)."""
        return "gossip"

    def serial_row(self, state: AlgoState) -> int | None:
        """The replica row the ``"ps-serial"`` batched variant serializes
        inside a fused cohort dispatch (all communicating events read-modify-
        write it in pop order).  ``None`` for variants without one."""
        return None

    def cache_token(self) -> tuple:
        """Hashable identity of this strategy's *traced* behavior
        (``delta_transform`` / mixing math).  The batched engine keys its
        compiled cohort-step cache on this, so two strategies with the same
        token share one XLA executable — in particular every identity-delta
        gossip algorithm (netmax / adpsgd / adpsgd+mon differ only in
        host-side peer/weight policy) compiles exactly once per process.
        Override when the constructor takes parameters that change traced
        computation (e.g. top-k ratio)."""
        if type(self).delta_transform is Algorithm.delta_transform:
            return ("identity-delta",)
        return (type(self).__module__, type(self).__qualname__)

    def __init__(self):
        self._mix_jit = None
        self._mix_stacked_jit = None
        self._stacked_round_jit = None

    # -- lifecycle ----------------------------------------------------------
    def init_state(self, cfg, M: int) -> AlgoState:
        return uniform_state(cfg, M)

    def wants_monitor(self, cfg) -> bool:
        """Whether the simulator should run a Network Monitor for this algo."""
        return False

    def make_monitor(self, cfg, M: int, d=None):
        """Build the Monitor; cfg.monitor_period (when set) is the single
        source of truth for the schedule period T_s, and ``d`` (the
        AlgoState connectivity mask) bounds the topology Algorithm 3
        optimizes over."""
        from repro.core.monitor import NetworkMonitor

        kw = dict(alpha=cfg.lr, K=cfg.policy_K, R=cfg.policy_R, d=d)
        period = getattr(cfg, "monitor_period", None)
        if period is not None:
            kw["schedule_period"] = float(period)
        home = getattr(cfg, "monitor_home_cluster", None)
        if home is not None:
            kw["home_cluster"] = int(home)
        if getattr(cfg, "monitor_failover", False):
            from repro.core.monitor import MonitorFailover

            kw["failover"] = MonitorFailover(
                lease_periods=getattr(cfg, "monitor_lease_periods", 1.0),
                quorum=getattr(cfg, "monitor_quorum", None),
            )
        return NetworkMonitor(M, **kw)

    def on_policy(self, state: AlgoState, pol) -> None:
        """Fold a fresh Monitor policy into host state."""
        state.P = guard_policy_rows(pol.P, state.d)

    # -- peer/group selection (host side, numpy RNG) ------------------------
    def select_peer(self, state: AlgoState, i: int, rng) -> int | None:
        """Async families: the neighbor worker i pulls from this event."""
        raise NotImplementedError(f"{self.name} is not event-driven")

    def select_groups(self, state: AlgoState, rng) -> list[list[int]]:
        """Sync families: the reduction groups for this round."""
        raise NotImplementedError(f"{self.name} is not round-based")

    # -- mixing semantics (pure JAX) ----------------------------------------
    def delta_transform(self, delta: jnp.ndarray) -> jnp.ndarray:
        """Hook on the consensus delta (x_pull - x_half) of ONE replica.

        Identity here; compression strategies (top-k, quantization) override.
        Must be jit-traceable; applied per worker row under vmap on the
        stacked path, so it sees unstacked leaf shapes in both substrates.
        """
        return delta

    def mix_weight(self, state: AlgoState, cfg, i: int, m: int) -> float:
        """Consensus weight w for worker i pulling from m (host side)."""
        return 0.5

    def mix(self, x_half, pulled, w):
        """Per-replica consensus mix: x_half + w * f(pulled - x_half)."""
        if self._mix_jit is None:

            def fn(h, p, w):
                return jax.tree_util.tree_map(
                    lambda a, b: a
                    + w.astype(a.dtype) * self.delta_transform(b - a),
                    h, p,
                )

            self._mix_jit = jax.jit(fn)
        return self._mix_jit(x_half, pulled, jnp.float32(w))

    def mix_stacked_tree(self, x_half, pulled, weights):
        """Un-jitted stacked consensus mix — THE leaf rule of this strategy.

        Leaves carry a leading worker/cohort axis; ``weights`` is (M,) f32.
        This single function is traced by three consumers: the jitted
        ``mix_stacked`` wrapper (SPMD trainer), ``stacked_round`` (parity
        reference), and the batched cohort engine's fused step
        (train/engine.py) — keeping them bit-for-bit consistent.
        """

        def leaf(h, p):
            # Cast weights into the param dtype so bf16 replicas stay
            # bf16 (matches dist/gossip.mix and optimizer.apply).
            w = weights.reshape((-1,) + (1,) * (h.ndim - 1)).astype(h.dtype)
            return h + w * jax.vmap(self.delta_transform)(p - h)

        return jax.tree_util.tree_map(leaf, x_half, pulled)

    def mix_stacked(self, x_half, pulled, weights):
        """Jitted ``mix_stacked_tree`` (the SPMD trainer's entry point)."""
        if self._mix_stacked_jit is None:
            self._mix_stacked_jit = jax.jit(self.mix_stacked_tree)
        return self._mix_stacked_jit(x_half, pulled, weights)

    def stacked_round(self, params, grads, neighbors, weights, alpha):
        """One lockstep gossip round on stacked replicas (SPMD reference).

        params/grads leaves: (M, ...); neighbors i32 (M,); weights f32 (M,).
        Pulls are *pre-round* neighbor params (Eq. 16), then the same
        leaf-level mix as the event-driven path — the parity tests assert
        both substrates agree given identical draws.
        """
        if self._stacked_round_jit is None:

            def fn(params, grads, neighbors, weights, alpha):
                pulled = jax.tree_util.tree_map(
                    lambda x: jnp.take(x, neighbors, axis=0), params
                )
                x_half = jax.tree_util.tree_map(
                    lambda x, g: x - jnp.asarray(alpha, x.dtype) * g,
                    params, grads,
                )
                return self.mix_stacked_tree(x_half, pulled, weights)

            self._stacked_round_jit = jax.jit(fn)
        return self._stacked_round_jit(params, grads, neighbors, weights, alpha)

    def transform_grads(self, grads, M: int):
        """SPMD trainer hook: grad reduction before the optimizer step
        (identity for gossip; global/group mean for collective families)."""
        return grads

    @property
    def communicates_in_trainer(self) -> bool:
        """Whether the SPMD train step performs a gossip pull + mix."""
        return self.family == "gossip"

    @property
    def supports_trainer(self) -> bool:
        """Whether the lockstep SPMD trainer can express this strategy.

        False for strategies whose semantics are inherently asynchronous
        and not reducible to grad reduction + gossip mix (ps-async);
        make_train_step raises rather than silently degrading.
        """
        return True

    # -- event application (async families) ---------------------------------
    def would_communicate(self, state: AlgoState, i: int, m: int | None) -> bool:
        """Host-side predicate: does worker i's event with peer m cross the
        network?  Must agree with ``apply_comm``'s return value — the batched
        engine uses it to price events *before* executing a cohort."""
        return m is not None and m != i and bool(state.d[i, m])

    def apply_comm(self, state: AlgoState, cfg, replicas, i, m, x_half):
        """Fold worker i's communication into the replica list.

        Default (gossip): replicas[i] <- mix(x_half, pre-event replicas[m]).
        Returns True when a transfer actually crossed the network.
        """
        if self.would_communicate(state, i, m):
            w = self.mix_weight(state, cfg, i, m)
            replicas[i] = self.mix(x_half, replicas[m], w)
            return True
        replicas[i] = x_half
        return False

    def apply_failed(self, state: AlgoState, cfg, replicas, i, x_half):
        """A scenario-dead link timed the pull out (repro.scenarios): the
        local grad step still commits, nothing is mixed, and no peer state
        is touched.  The event's *timing* is still priced as an attempted
        transfer (the timeout) by ``event_timing``."""
        replicas[i] = x_half

    # -- timing semantics ---------------------------------------------------
    def event_timing(
        self, state: AlgoState, cfg, link, i: int, m: int | None,
        communicated: bool, t: float,
    ) -> Timing:
        """Async duration model: overlap of compute and the (optional) pull."""
        raw = link.iteration_time(i, m, now=t) if communicated else None
        net = raw * self.wire_ratio() if communicated else 0.0
        comp = link.compute_time
        if getattr(cfg, "serial_compute", False):
            return Timing(duration=comp + net, comm=net, compute=comp, net=raw)
        return Timing(duration=max(comp, net), comm=max(0.0, net - comp),
                      compute=comp, net=raw)

    def round_timing(self, state: AlgoState, cfg, link, groups, t: float) -> Timing:
        raise NotImplementedError(f"{self.name} is not round-based")

    def wire_ratio(self) -> float:
        """Bytes-on-the-wire ratio vs a dense f32 pull (compression hook)."""
        return 1.0

    # -- round application (sync families) ----------------------------------
    def reduce_groups(self, replicas, groups):
        """Average replicas within each reduction group (pure JAX).

        Reference-engine form: per-replica pytrees, one Python mean per
        group.  The batched engine executes the same semantics through
        ``reduce_groups_stacked`` — overriding this method without also
        overriding the stacked form drops the strategy back to the
        reference engine (``supports_batched``)."""
        for grp in groups:
            if len(grp) < 2:
                continue
            mean_p = mean_params([replicas[i] for i in grp])
            for i in grp:
                replicas[i] = mean_p

    def reduce_groups_stacked(self, x, gid):
        """Stacked-tree group averaging: one segment-mean per leaf.

        ``x`` leaves are (M, ...) stacked replicas; ``gid`` is an (M,) i32
        segment id per worker (workers sharing an id form one reduction
        group; singletons map to themselves and pass through exactly).
        This is the one-dispatch form of ``reduce_groups`` the batched sync
        engine jits (DESIGN.md §12)."""
        from repro.kernels import ops as kops

        M = gid.shape[0]
        return jax.tree_util.tree_map(
            lambda l: kops.segment_mean_rows(l, gid, M), x
        )

    def __repr__(self):
        return f"<Algorithm {self.name} family={self.family}>"


def mean_params(replicas):
    return jax.tree_util.tree_map(lambda *xs: sum(xs) / len(xs), *replicas)


def global_mean_grads(grads):
    """Mean over the stacked worker dim, broadcast back — lowers to an
    all-reduce along the worker mesh axes in the SPMD trainer."""
    return jax.tree_util.tree_map(
        lambda g: jnp.broadcast_to(g.mean(axis=0, keepdims=True), g.shape),
        grads,
    )
