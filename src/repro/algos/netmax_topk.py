"""NetMax + top-k sparsified pulls (SAPS-style; Tang et al., 2020).

The new strategy the unified ``Algorithm`` API exists for: NetMax's adaptive
peer selection (Alg. 3 policy, gamma-weighted mixing) combined with
sparsifying the consensus delta ``w * (x_pull - x_half)`` before it crosses
the link, via the existing ``core/compression.py`` top-k operator.

Two effects, one strategy:

* **mixing** — only the k largest-magnitude delta entries move, so the mix
  stays a contraction on the kept coordinates (bounded extra noise absorbed
  into sigma^2 of Thm. 1, like DESIGN.md §8.3's error-feedback analysis);
* **timing** — wire bytes shrink to ~2*ratio of a dense f32 pull (value +
  index per kept entry), so slow links cost proportionally less virtual time.
"""

from __future__ import annotations

from repro.algos.base import register
from repro.algos.netmax import NetMax
from repro.core.compression import topk_mask


@register("netmax-topk")
class NetMaxTopK(NetMax):
    """NetMax peer selection, top-k sparsified consensus delta."""

    def __init__(self, ratio: float = 0.05):
        super().__init__()
        assert 0.0 < ratio <= 1.0
        self.ratio = float(ratio)

    def cache_token(self) -> tuple:
        # ratio changes the traced delta_transform (static k), so instances
        # with different ratios must not share a compiled cohort step.
        return super().cache_token() + (self.ratio,)

    def delta_transform(self, delta):
        flat = delta.reshape(-1)
        k = max(1, int(self.ratio * flat.size))
        return topk_mask(flat, k).reshape(delta.shape)

    def wire_ratio(self) -> float:
        # value + int32 index per kept entry vs dense f32.
        return min(1.0, 2.0 * self.ratio)
