"""Synchronous collective strategies: Allreduce-SGD and Prague.

  allreduce  all workers step together; ring allreduce bottlenecked by the
             slowest link in the ring (paper §V baselines)
  prague     random groups of g workers partial-allreduce per iteration;
             concurrent groups contend for shared links (paper §V-B)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.algos.base import (
    Algorithm,
    AlgoState,
    Timing,
    global_mean_grads,
    register,
)


class SynchronousAlgorithm(Algorithm):
    # Round-barrier semantics.  Both engines share the same host-side round
    # machinery (select_groups -> round_timing -> per-worker grad step ->
    # group averaging); the batched engine executes each round as a single
    # jitted dispatch over stacked trees via reduce_groups_stacked
    # (supports_batched is True as long as reduce_groups stays the default).
    family = "collective"
    synchronous = True
    reports_ema = False


@register("allreduce")
class Allreduce(SynchronousAlgorithm):
    """Synchronous Allreduce-SGD: one global reduction group per round."""

    def select_groups(self, state: AlgoState, rng):
        return [list(range(state.M))]

    def round_timing(self, state, cfg, link, groups, t):
        M = state.M
        ring = [(i, (i + 1) % M) for i in range(M)]
        step_t = max(link.iteration_time(i, j, now=t) for i, j in ring)
        comm = step_t * 2 * (M - 1) / M  # 2(M-1)/M ring phases
        comp = link.compute_time
        return Timing(duration=comp + comm, comm=comm, compute=comp)

    def transform_grads(self, grads, M):
        return global_mean_grads(grads)


@register("prague")
class Prague(SynchronousAlgorithm):
    """Prague-style random-group partial-allreduce.

    ``trainer_groups`` configures the SPMD trainer path (number of contiguous
    worker groups per round); the simulator path reads the group *size* from
    ``cfg.prague_group`` and the contention factor from
    ``cfg.prague_contention``.
    """

    def __init__(self, trainer_groups: int = 2):
        super().__init__()
        self.trainer_groups = trainer_groups

    def select_groups(self, state: AlgoState, rng):
        order = rng.permutation(state.M)
        g = state.extras.get("group_size", 4)
        return [
            [int(w) for w in order[s : s + g]]
            for s in range(0, state.M, g)
        ]

    def init_state(self, cfg, M):
        state = super().init_state(cfg, M)
        state.extras["group_size"] = getattr(cfg, "prague_group", 4)
        return state

    def round_timing(self, state, cfg, link, groups, t):
        # Concurrent partial-allreduces compete for shared bandwidth
        # (paper §V-B); each extra *actual* reducing group (>= 2 members)
        # inflates ring time by this factor.
        n_groups = max(1, sum(1 for grp in groups if len(grp) >= 2))
        congestion = 1.0 + getattr(cfg, "prague_contention", 0.5) * (n_groups - 1)
        comm = 0.0
        for grp in groups:
            if len(grp) < 2:
                continue
            ring = [(grp[a], grp[(a + 1) % len(grp)]) for a in range(len(grp))]
            ct = max(link.iteration_time(i, j, now=t) for i, j in ring)
            comm = max(comm, ct * 2 * (len(grp) - 1) / len(grp) * congestion)
        comp = link.compute_time
        return Timing(duration=comp + comm, comm=comm, compute=comp)

    def transform_grads(self, grads, M):
        G = self.trainer_groups
        if G <= 1:
            return grads
        if M % G:
            raise ValueError(
                f"prague: M={M} workers not divisible into {G} groups"
            )

        def group_mean(g):
            gg = g.reshape((G, M // G) + g.shape[1:])
            gg = jnp.broadcast_to(gg.mean(axis=1, keepdims=True), gg.shape)
            return gg.reshape(g.shape)

        return jax.tree_util.tree_map(group_mean, grads)
