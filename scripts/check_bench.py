"""CI bench-regression gate (DESIGN.md §14).

Compares a freshly-run benchmark JSON against the committed baseline and
fails (exit 1) when a speedup-style metric regressed by more than the
tolerance.  Only ratio metrics are compared — wall-clock seconds differ
across runner hardware, but batched-vs-reference speedup, chain-fusion
dispatch reduction, and warm-vs-cold pivot counts are hardware-portable
(pivot counts are fully deterministic).  Only keys present in BOTH files
are compared, so the CI smoke can run a subset of the committed sweep
(e.g. ``--sim-sizes 8 32`` against a baseline swept to M=128).

Usage (what .github/workflows/ci.yml runs):

    python benchmarks/run.py --suite simulator --sim-sizes 8 32 --out-dir artifacts
    python scripts/check_bench.py --suite simulator \
        --fresh artifacts/BENCH_simulator.json --baseline BENCH_simulator.json
"""

from __future__ import annotations

import argparse
import json
import sys


def _walk_simulator(doc):
    """Yield (key, metric, value) ratio metrics from BENCH_simulator.json.

    Fleet rows gate ``cost_ratio_vs_base`` — us/event(base M) over
    us/event(M), higher is better — so a >30% per-event-cost regression at
    fleet scale fails CI, and ``link_state_savings`` — dense-equivalent
    bytes over actual link-state bytes — so the sparse O(M) representation
    can't silently densify.  Both are hardware-portable ratios.
    """
    for algo, by_size in doc.get("results", {}).items():
        for size, row in by_size.items():
            yield f"{algo}/{size}", "speedup", row.get("speedup")
            yield f"{algo}/{size}", "dispatch_reduction", row.get("dispatch_reduction")
    for size, row in doc.get("fleet", {}).get("results", {}).items():
        yield f"fleet/{size}", "cost_ratio_vs_base", row.get("cost_ratio_vs_base")
        yield f"fleet/{size}", "link_state_savings", row.get("link_state_savings")


def _walk_policy(doc):
    """Yield ratio metrics from BENCH_policy.json: warm-start effectiveness
    as the deterministic pivot ratio + hit rate.  (speedup_vs_dense is
    deliberately NOT gated: a wall/wall ratio of two sub-second solves
    swings ~2x with runner load; the pivot counts carry the same signal
    bit-exactly.)"""
    for topo, by_size in doc.get("results", {}).items():
        for size, row in by_size.items():
            pw, pc = row.get("pivots_warm"), row.get("pivots_cold")
            if pw and pc:
                yield f"{topo}/{size}", "pivot_ratio_cold_over_warm", pc / pw
            yield f"{topo}/{size}", "warm_hit_rate", row.get("warm_hit_rate")


def _walk_trace(doc):
    """Yield ratio metrics from BENCH_trace.json: replay fidelity and
    calibration quality per algorithm (both are accuracies in (0, 1], so
    the regression floor is meaningful on any hardware), plus the headline
    ordering/what-if speedups.  Raw wall-clock seconds are not gated."""
    for algo, row in doc.get("results", {}).items():
        yield algo, "replay_accuracy", row.get("replay_accuracy")
        # Compression strategies (netmax-topk) record observed durations
        # that embed the top-k wire ratio, which LinkTimeModel cannot
        # represent — their calibration accuracy goes negative by design,
        # flipping the sign of the `baseline * (1 - tol)` floor.  Clamp to
        # 0 so such rows gate as "no calibration" rather than breaking the
        # floor math, while a drop from a positive baseline still fails.
        acc = row.get("calibration_accuracy")
        if isinstance(acc, (int, float)):
            yield algo, "calibration_accuracy", max(float(acc), 0.0)
    s = doc.get("summary", {})
    for k in (
        "netmax_speedup_vs_adpsgd",
        "adpsgd_speedup_vs_allreduce",
        "whatif_upgrade_speedup",
        "whatif_switch_ttl_speedup",
        "fixture_calibration_accuracy",
        "ordering_ok",  # bool -> 1/0: any False against a True baseline fails
    ):
        yield "summary", k, s.get(k)


def _walk_serve(doc):
    """Yield ratio metrics from BENCH_serve.json (PR 8 serving hot path).

    Gated: the deterministic pivot-reduction of the warm auto sweep over
    the Dantzig-cold baseline (ISSUE floor >= 2x at M >= 128, committed
    baseline ~9x), the no-uniform-fallback flag (1/0 — any fallback at
    M >= 128 is the pre-PR blowup), the served cache hit rate, the
    p99-is-a-cache-hit flag, the batched-sweep grid-point agreement, the
    jax-sweep grid-point agreement (PR 10), and the RPC service
    all-answered flags at each shard count (PR 10 — overload sheds, it
    never errors or hangs).  Wall-clock fields (warm_first_s, p50_ms,
    requests_per_s, shed_rate, jax_compile_s, ...) are deliberately NOT
    gated — they move with runner hardware and load; the ratios and
    flags above carry the regression signal portably."""
    for size, row in doc.get("pricing", {}).items():
        yield f"pricing/{size}", "pivot_reduction_vs_dantzig", row.get(
            "pivot_reduction_vs_dantzig"
        )
        yield f"pricing/{size}", "no_uniform_fallback", row.get(
            "no_uniform_fallback"
        )
        yield f"pricing/{size}", "warm_hit_rate", row.get("warm_hit_rate")
    serving = doc.get("serving", {})
    yield "serving", "cache_hit_rate", serving.get("cache_hit_rate")
    yield "serving", "p99_is_hit", serving.get("p99_is_hit")
    batched = doc.get("batched", {})
    yield "batched", "same_grid_point_batched", batched.get(
        "same_grid_point_batched"
    )
    yield "jax", "same_grid_point_jax", doc.get("jax", {}).get(
        "same_grid_point_jax"
    )
    for shards, row in doc.get("service", {}).items():
        yield f"service/{shards}", "all_answered", row.get("all_answered")


def _walk_storms(doc):
    """Yield ratio metrics from BENCH_storms.json (PR 9 robustness suite).

    Everything gated here is derived from seeded virtual-time simulation or
    a seeded chaos stream — no wall clocks — so fresh-vs-baseline should
    match bit-for-bit on any hardware; the 30% tolerance only absorbs
    cross-version RNG drift.  Gated: netmax-vs-adpsgd throughput through
    the storm (events per *virtual* second), the failover acceptance flags
    (a pinned Monitor never reroutes, a standby election does) and the
    far-side dead-pull-rate reduction failover buys, and the
    degraded-serving flags
    (every request answered under 35% faults and under total blackout,
    breaker trips then recovers).  p50/p99 latencies and wall seconds are
    deliberately NOT gated."""
    th = doc.get("throughput", {})
    yield "throughput", "netmax_vs_adpsgd_evps", th.get("netmax_vs_adpsgd_evps")
    fo = doc.get("failover", {})
    for k in (
        "pinned_never_reroutes",
        "reroutes_with_failover",
        "dead_pull_rate_reduction",
    ):
        yield "failover", k, fo.get(k)
    serving = doc.get("serving", {})
    yield "serving", "all_served", serving.get("all_served")
    blackout = serving.get("blackout", {})
    for k in ("served_under_blackout", "breaker_tripped", "breaker_recovered"):
        yield "serving/blackout", k, blackout.get(k)


_WALKERS = {
    "simulator": _walk_simulator,
    "policy": _walk_policy,
    "trace": _walk_trace,
    "serve": _walk_serve,
    "storms": _walk_storms,
}


def collect(suite: str, doc) -> dict:
    return {
        (key, metric): value
        for key, metric, value in _WALKERS[suite](doc)
        if isinstance(value, (int, float))
    }


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--suite", required=True, choices=sorted(_WALKERS))
    ap.add_argument("--fresh", required=True, help="JSON produced by this CI run")
    ap.add_argument(
        "--baseline", required=True, help="committed BENCH_*.json baseline"
    )
    tol_help = (
        "max allowed fractional regression (default 0.30: fail when "
        "fresh < 0.7 * baseline)"
    )
    ap.add_argument("--tolerance", type=float, default=0.30, help=tol_help)
    args = ap.parse_args()

    with open(args.fresh) as f:
        fresh = collect(args.suite, json.load(f))
    with open(args.baseline) as f:
        base = collect(args.suite, json.load(f))

    shared = sorted(set(fresh) & set(base))
    if not shared:
        msg = (
            f"check_bench[{args.suite}]: no overlapping metrics between "
            f"{args.fresh} and {args.baseline}"
        )
        print(msg, file=sys.stderr)
        return 1

    failures = []
    for key in shared:
        b, f_ = base[key], fresh[key]
        floor = b * (1.0 - args.tolerance)
        status = "FAIL" if f_ < floor else "ok"
        line = (
            f"check_bench[{args.suite}] {status:4s} {key[0]} {key[1]}: "
            f"fresh={f_:.3g} baseline={b:.3g} floor={floor:.3g}"
        )
        print(line)
        if f_ < floor:
            failures.append(key)

    if failures:
        msg = (
            f"check_bench[{args.suite}]: {len(failures)}/{len(shared)} "
            f"metrics regressed beyond {args.tolerance:.0%}"
        )
        print(msg, file=sys.stderr)
        return 1
    msg = (
        f"check_bench[{args.suite}]: {len(shared)} metrics within "
        f"{args.tolerance:.0%} of baseline"
    )
    print(msg)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
