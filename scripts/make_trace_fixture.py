"""Regenerate the committed trace fixture (tests/fixtures/).

    PYTHONPATH=src python scripts/make_trace_fixture.py

Runs a small, fully-seeded heterogeneous simulation — two WAN-separated
clusters of four workers, netmax with a fast Monitor, a brief cluster
outage so the trace carries ``timeout`` records alongside ``pull`` /
``local`` / ``refresh`` — and writes it as a v1 JSONL trace.  The fixture
is what lets the ingest/calibrate tests, the CI summarizer sanity-print,
and ``benchmarks/run.py --suite trace`` run without a prior simulation.

Deterministic: same seeds, same file, byte for byte.
"""

from __future__ import annotations

import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

FIXTURE = ROOT / "tests" / "fixtures" / "trace_hetero_M8.jsonl"

M = 8
EVENTS = 600
SEED = 0
LINK_SEED = 5


def build_trace():
    from repro.core.nettime import LinkTimeModel, Topology
    from repro.data.partition import uniform_partition
    from repro.data.synthetic import train_eval_split
    from repro.scenarios import ClusterOutage, Timeline
    from repro.train.simulator import SimConfig, simulate
    from repro.trace import from_sim_result

    topo = Topology.multi_cluster(M, workers_per_host=2, hosts_per_pod=1,
                                  pods_per_cluster=2)  # 2 clusters of 4
    timeline = Timeline([ClusterOutage(1, 2.0, 4.0)])
    link = LinkTimeModel(topo, jitter=0.05, seed=LINK_SEED,
                         scenario=timeline, dead_link_timeout=2.0)
    x, y, ex, ey = train_eval_split(1600, 400, 32, 10, seed=0)
    parts = uniform_partition(len(y), M, seed=0)
    cfg = SimConfig(algorithm="netmax", n_workers=M, total_events=EVENTS,
                    lr=0.05, monitor_period=1.5, seed=SEED, trace=True)
    res = simulate(cfg, link, x, y, parts, ex, ey, record_every=200)
    assert res.failed_pulls, "fixture should carry timeout records"
    return from_sim_result(res, cfg=cfg, link_model=link)


def main() -> int:
    from repro.trace import write_jsonl

    trace = build_trace()
    FIXTURE.parent.mkdir(parents=True, exist_ok=True)
    write_jsonl(trace, FIXTURE)
    counts = trace.counts()
    print(f"wrote {FIXTURE} ({len(trace.records)} records: "
          f"{', '.join(f'{k}={v}' for k, v in counts.items() if v)})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
