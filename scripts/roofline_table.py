"""Render the EXPERIMENTS.md roofline table from dry-run records."""

import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

from repro.analysis.roofline import fix_suggestion, from_record  # noqa: E402
from repro.configs.base import SHAPES  # noqa: E402


def load(mesh_filter=None):
    recs = {}
    for line in open(ROOT / "artifacts/dryrun/records.jsonl"):
        r = json.loads(line)
        if mesh_filter and r["mesh"] != mesh_filter:
            continue
        recs[(r["mesh"], r["arch"], r["shape"])] = r
    return recs


def main(mesh="16x16"):
    recs = load(mesh)
    rows = []
    for (m, a, s), r in sorted(recs.items()):
        if r.get("skipped"):
            rows.append((a, s, None, r.get("reason", "skipped")))
            continue
        if not r["ok"]:
            rows.append((a, s, None, "FAILED"))
            continue
        rl = from_record(r, SHAPES[s])
        rows.append((a, s, rl, r))
    print(f"| arch | shape | compute s | memory s | collective s | dominant | "
          f"useful 6ND/HLO | roofline frac | fix |")
    print("|---|---|---|---|---|---|---|---|---|")
    hill = []
    for a, s, rl, extra in rows:
        if rl is None:
            print(f"| {a} | {s} | — | — | — | skipped | — | — | {extra} |")
            continue
        fix = fix_suggestion(rl)
        print(f"| {a} | {s} | {rl.compute_s:.2e} | {rl.memory_s:.2e} | "
              f"{rl.collective_s:.2e} | {rl.dominant} | {rl.useful_ratio:.3f} | "
              f"{rl.roofline_fraction:.4f} | {fix.split(':')[0]} |")
        hill.append((rl.roofline_fraction, rl.collective_s / max(rl.bound_time, 1e-12), a, s, rl.dominant))
    hill.sort()
    print("\nWorst roofline fractions:")
    for f, cr, a, s, dom in hill[:6]:
        print(f"  {f:.4f}  {a}/{s} (dom={dom}, coll-share={cr:.2f})")
    print("\nMost collective-bound:")
    for f, cr, a, s, dom in sorted(hill, key=lambda t: -t[1])[:6]:
        print(f"  coll-share={cr:.2f}  frac={f:.4f}  {a}/{s}")


if __name__ == "__main__":
    main(*sys.argv[1:])
