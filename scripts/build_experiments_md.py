"""Assemble EXPERIMENTS.md from artifacts (dry-run records, bench results)."""

import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

from repro.analysis.roofline import from_record  # noqa: E402
from repro.configs.base import SHAPES  # noqa: E402


def load_records(path):
    recs = {}
    for line in open(path):
        r = json.loads(line)
        recs[(r["mesh"], r["arch"], r["shape"], r.get("opt", ""))] = r
    return recs


def roofline_rows(recs, mesh):
    rows = []
    for (m, a, s, o), r in sorted(recs.items()):
        if m != mesh or o:
            continue
        if r.get("skipped"):
            rows.append(f"| {a} | {s} | — | — | — | skip | — | — |")
            continue
        if not r["ok"]:
            rows.append(f"| {a} | {s} | FAILED | | | | | |")
            continue
        rl = from_record(r, SHAPES[s])
        rows.append(
            f"| {a} | {s} | {rl.compute_s:.2e} | {rl.memory_s:.2e} | "
            f"{rl.collective_s:.2e} | {rl.dominant} | {rl.useful_ratio:.3f} | "
            f"{rl.roofline_fraction:.4f} |"
        )
    return rows


def dryrun_rows(recs, mesh):
    rows = []
    for (m, a, s, o), r in sorted(recs.items()):
        if m != mesh or o or not r.get("ok"):
            continue
        mem = r.get("memory_analysis", {})
        temp = mem.get("temp_size_in_bytes", 0) / 1e9
        arg = mem.get("argument_size_in_bytes", 0) / 1e9
        coll = ", ".join(
            f"{k.split('-')[-1] if False else k}:{v:.2e}"
            for k, v in sorted(r["collective_bytes_per_device"].items())
        )
        rows.append(
            f"| {a} | {s} | {r['program']} | {r.get('M','')} | "
            f"{r['t_compile_s']:.0f}s | {arg:.2f} | {temp:.2f} | {coll} |"
        )
    return rows


def main():
    recs = load_records(ROOT / "artifacts/dryrun/records.jsonl")
    hdr_roof = ("| arch | shape | compute s | memory s | collective s | dominant | "
                "6ND/HLO | roofline frac |\n|---|---|---|---|---|---|---|---|")
    hdr_dry = ("| arch | shape | program | M | compile | args GB/dev | temp GB/dev | "
               "collective bytes/dev |\n|---|---|---|---|---|---|---|---|")
    out = {
        "ROOF16": "\n".join([hdr_roof] + roofline_rows(recs, "16x16")),
        "ROOF512": "\n".join([hdr_roof] + roofline_rows(recs, "2x16x16")),
        "DRY16": "\n".join([hdr_dry] + dryrun_rows(recs, "16x16")),
        "DRY512": "\n".join([hdr_dry] + dryrun_rows(recs, "2x16x16")),
    }
    for k, v in out.items():
        (ROOT / f"artifacts/{k}.md").write_text(v)
        print(f"wrote artifacts/{k}.md ({v.count(chr(10))} lines)")


if __name__ == "__main__":
    main()
