"""CI doc-drift gate (DESIGN.md §19).

Two checks keep the prose honest:

1. Every fenced ``python`` block in README.md is *executed* (in order,
   each in a fresh namespace, with ``src/`` on ``sys.path``) — the
   quickstart is living documentation, and an API rename that breaks it
   fails CI instead of rotting silently.
2. Every ``§N`` section reference in README.md and docs/serving.md must
   name a ``## §N`` heading that actually exists in DESIGN.md.

Usage (what .github/workflows/ci.yml runs):

    python scripts/check_docs.py

Exit 0 when every block runs and every reference resolves; exit 1 with
a per-failure report otherwise.
"""

from __future__ import annotations

import re
import sys
import time
import traceback
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

# Files whose python blocks are executed.  docs/serving.md's blocks are
# deployment sketches (they bind real ports and reference operator
# infrastructure), so they are reference-checked but not executed.
EXEC_DOCS = ["README.md"]
REF_DOCS = ["README.md", "docs/serving.md"]

_FENCE = re.compile(r"^```python\s*$(.*?)^```\s*$", re.M | re.S)
_SECTION_REF = re.compile(r"§+(\d+)")
_SECTION_DEF = re.compile(r"^## §(\d+)\b", re.M)


def python_blocks(text: str) -> list[tuple[int, str]]:
    """Return (1-indexed start line, source) for each ```python fence."""
    out = []
    for m in _FENCE.finditer(text):
        line = text.count("\n", 0, m.start(1)) + 1
        out.append((line, m.group(1)))
    return out


def check_quickstart(failures: list[str]) -> None:
    """Execute every README python block; record tracebacks as failures."""
    sys.path.insert(0, str(REPO / "src"))
    for doc in EXEC_DOCS:
        text = (REPO / doc).read_text()
        blocks = python_blocks(text)
        if not blocks:
            failures.append(f"{doc}: no ```python quickstart blocks found")
            continue
        for line, src in blocks:
            t0 = time.perf_counter()
            try:
                exec(compile(src, f"{doc}:{line}", "exec"), {"__name__": "__docs__"})
            except Exception:
                tb = traceback.format_exc(limit=4)
                failures.append(f"{doc}:{line} quickstart block raised:\n{tb}")
            else:
                dt = time.perf_counter() - t0
                print(f"check_docs: ok    {doc}:{line} block ran ({dt:.1f}s)")


def check_section_refs(failures: list[str]) -> None:
    """Every §N mentioned in the docs must exist as a DESIGN.md heading."""
    defined = {int(n) for n in _SECTION_DEF.findall((REPO / "DESIGN.md").read_text())}
    if not defined:
        failures.append("DESIGN.md: no '## §N' headings found")
        return
    for doc in REF_DOCS:
        text = (REPO / doc).read_text()
        refs = sorted({int(n) for n in _SECTION_REF.findall(text)})
        missing = [n for n in refs if n not in defined]
        for n in missing:
            failures.append(f"{doc}: references DESIGN.md §{n}, which does not exist")
        print(
            f"check_docs: ok    {doc} references §{{{', '.join(map(str, refs))}}}"
            f" ({len(refs) - len(missing)}/{len(refs)} resolve)"
        )


def main() -> int:
    """Run both checks and report; non-zero exit on any failure."""
    failures: list[str] = []
    check_section_refs(failures)
    check_quickstart(failures)
    if failures:
        print(f"\ncheck_docs: {len(failures)} failure(s)", file=sys.stderr)
        for f in failures:
            print(f"  FAIL  {f}", file=sys.stderr)
        return 1
    print("check_docs: all quickstart blocks ran, all § references resolve")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
