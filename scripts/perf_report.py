"""§Perf report: baseline vs hillclimb variants for the three chosen cells.

For each record in records_opt.jsonl, prints the three roofline terms and
deltas vs the matching baseline; also computes the "Pallas projection" for
the memory term: HBM traffic with attention-internal carry round-trips
removed (the Pallas flash kernel keeps online-softmax state in VMEM; its
HBM traffic is just the q/k/v/o streams, which are already counted at the
scan boundary fusions).
"""

import gzip
import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

from repro.analysis.breakdown import Breakdown  # noqa: E402
from repro.analysis.roofline import HBM_BW, LINK_BW, PEAK_FLOPS  # noqa: E402


def attn_internal_bytes(hlo_path: Path) -> tuple[float, float]:
    """(total bytes, bytes inside chunked_attention scopes) per device."""
    bd = Breakdown(gzip.open(hlo_path, "rt").read())
    total = bd.entry_cost().bytes_accessed
    tops = bd.top(100000)
    attn = sum(
        c.value for c in tops["bytes"] if "chunked_attention" in c.scope
    )
    return total, attn


def load(path):
    recs = []
    if Path(path).exists():
        for line in open(path):
            recs.append(json.loads(line))
    return recs


def fmt(rec, base=None):
    c = rec["hlo_flops_per_device"] / PEAK_FLOPS
    m = rec["hlo_bytes_per_device"] / HBM_BW
    x = sum(rec["collective_bytes_per_device"].values()) / LINK_BW
    bound = max(c, m, x)
    out = (f"  compute={c:8.3f}s  memory={m:8.3f}s  collective={x:8.3f}s  "
           f"bound={bound:8.3f}s")
    if base is not None:
        bc = base["hlo_flops_per_device"] / PEAK_FLOPS
        bm = base["hlo_bytes_per_device"] / HBM_BW
        bx = sum(base["collective_bytes_per_device"].values()) / LINK_BW
        bb = max(bc, bm, bx)
        out += (f"   Δcompute={100*(c-bc)/bc:+6.1f}%  Δmem={100*(m-bm)/bm:+6.1f}%  "
                f"Δcoll={100*(x-bx)/max(bx,1e-12):+6.1f}%  Δbound={100*(bound-bb)/bb:+6.1f}%")
    return out


def main():
    opt = load(ROOT / "artifacts/dryrun/records_opt.jsonl")
    baselines = {}
    for r in opt:
        if r.get("ok") and not r.get("opt") and r.get("gossip") == "ppermute":
            baselines[(r["arch"], r["shape"])] = r
    print("== §Perf hillclimb results ==")
    for r in opt:
        if not r.get("ok"):
            if not r.get("skipped"):
                print(f"FAILED {r['arch']}/{r['shape']} opt={r.get('opt')}: "
                      f"{r.get('error', '')[:80]}")
            continue
        base = baselines.get((r["arch"], r["shape"]))
        tag = r.get("opt") or f"gossip={r['gossip']}"
        is_base = base is r
        print(f"\n{r['arch']}/{r['shape']} [{tag}]{' (baseline)' if is_base else ''}")
        print(fmt(r, None if is_base else base))
        if "temp_size_in_bytes" in r.get("memory_analysis", {}):
            print(f"  temp memory/device: {r['memory_analysis']['temp_size_in_bytes']/1e9:.2f} GB")

    # Pallas projection on the three baseline cells
    print("\n== Pallas flash-kernel memory projection (attention-internal "
          "carry traffic held in VMEM) ==")
    for arch, shape in [("tinyllama-1.1b", "train_4k"), ("stablelm-12b", "train_4k"),
                        ("llama4-maverick-400b-a17b", "train_4k")]:
        for name in (f"16x16_{arch}_{shape}_padheads.hlo.gz", f"16x16_{arch}_{shape}.hlo.gz"):
            p = ROOT / "artifacts/dryrun" / name
            if p.exists():
                total, attn = attn_internal_bytes(p)
                print(f"{arch}/{shape} [{name.split('_')[-1][:-7] or 'base'}]: "
                      f"memory {total/HBM_BW:.2f}s -> {(total-attn)/HBM_BW:.2f}s "
                      f"({100*attn/total:.0f}% was attention-internal)")
                break


if __name__ == "__main__":
    main()
